"""End-to-end launcher tests (subprocess: the CLIs users actually run).

Covers the three drivers: the 512-device dry-run (one cheap cell), the
training driver's failure-drill/auto-resume contract, and the serve loop.
"""
import os
import subprocess
import sys
import tempfile

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable] + args, cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_driver_backend_defaults_are_auto():
    """Both drivers must default --backend to the registry's blessed
    'auto' entry point (regression: serve.py shipped with 'xla_masked'
    while train.py and the sparsity registry treated 'auto' as canonical)."""
    from repro.launch import serve as serve_cli
    from repro.launch import train as train_cli

    assert serve_cli.build_parser().get_default("backend") == "auto"
    assert train_cli.build_parser().get_default("backend") == "auto"


def test_serve_parser_has_engine_knobs():
    from repro.launch import serve as serve_cli

    ap = serve_cli.build_parser()
    assert ap.get_default("engine") == "continuous"
    assert ap.get_default("page_size") == 8
    assert ap.get_default("max_live_tokens") == 0
    # sharded-serving knobs (PR 6): engine choices + mesh/chunk defaults
    engine_action = next(a for a in ap._actions if a.dest == "engine")
    assert engine_action.choices == ["static", "continuous", "sharded",
                                     "disagg"]
    assert ap.get_default("mesh") == ""
    assert ap.get_default("prefill_chunk") == 0
    args = ap.parse_args(["--engine", "disagg", "--mesh", "1,2,2",
                          "--prefill-chunk", "8"])
    assert (args.engine, args.mesh, args.prefill_chunk) == \
        ("disagg", "1,2,2", 8)


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "cell.jsonl"
    res = run_cli(["-m", "repro.launch.dryrun", "--arch", "tinyllama-1.1b",
                   "--shape", "decode_32k", "--mesh", "single",
                   "--out", str(out)])
    assert res.returncode == 0, res.stdout[-500:] + res.stderr[-500:]
    import json

    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["mesh"] == "16x16"
    assert rec["memory"]["peak_per_device_gb"] < 16, "must fit a v5e chip"
    assert rec["collectives"]["total_bytes"] >= 0
    assert {"compute_s", "memory_s", "collective_s"} <= set(rec["roofline"])


@pytest.mark.slow
def test_train_failure_drill_and_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    base = ["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
            "--reduced", "--steps", "12", "--batch", "2", "--seq", "16",
            "--checkpoint-every", "4", "--checkpoint-dir", ckpt]
    # 1) crash at step 7 -> exit 42, checkpoint from step 4 durable
    res = run_cli(base + ["--simulate-failure", "7"])
    assert res.returncode == 42, res.stdout[-400:] + res.stderr[-400:]
    assert "FAILURE DRILL" in res.stdout
    # 2) rerun the identical command: auto-resume and complete
    res2 = run_cli(base)
    assert res2.returncode == 0, res2.stdout[-400:] + res2.stderr[-400:]
    assert "auto-resumed" in res2.stdout
    assert "done: steps=12" in res2.stdout


@pytest.mark.slow
def test_train_and_serve_accept_plan_file(tmp_path):
    """--plan wires a SparsityPlan JSON through both drivers: the plan is
    actually applied (fingerprint echoed, stamped into checkpoints) and a
    rerun under a storage-incompatible plan is refused."""
    from repro.sparsity import PatternSpec, SparsityPlan

    plan = SparsityPlan.uniform(
        PatternSpec(pattern="rbgp4", sparsity=0.5, backend="xla_masked",
                    min_dim=64))
    plan_file = tmp_path / "plan.json"
    plan.save(str(plan_file))
    ckpt = str(tmp_path / "ckpt")
    base = ["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
            "--reduced", "--steps", "4", "--batch", "2", "--seq", "16",
            "--checkpoint-every", "2", "--checkpoint-dir", ckpt]
    res = run_cli(base + ["--plan", str(plan_file)])
    assert res.returncode == 0, res.stdout[-400:] + res.stderr[-400:]
    assert f"plan={plan.fingerprint()}" in res.stdout  # plan really applied
    # resuming the same dir WITHOUT the plan (uniform 0.75 flags) must hit
    # the fingerprint guard, not silently scramble masks
    res2 = run_cli(base)
    assert res2.returncode != 0
    assert "was written under sparsity plan" in res2.stderr
    # serve accepts the same plan file
    res3 = run_cli(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                    "--reduced", "--batch", "2", "--prompt-len", "8",
                    "--gen", "4", "--plan", str(plan_file)])
    assert res3.returncode == 0, res3.stdout[-400:] + res3.stderr[-400:]
    assert f"plan={plan.fingerprint()}" in res3.stdout


@pytest.mark.slow
def test_serve_driver():
    res = run_cli(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--reduced", "--batch", "2", "--prompt-len", "8",
                   "--gen", "4"])
    assert res.returncode == 0, res.stdout[-400:] + res.stderr[-400:]
    assert "decode" in res.stdout and "tok/s" in res.stdout
    assert "paged KV" in res.stdout   # default engine is continuous


@pytest.mark.slow
def test_serve_driver_observability_roundtrip(tmp_path):
    """--json/--trace/--prom attach a Recorder and write the documented
    artifacts: the JSON keeps every legacy top-level key (schema contract
    in src/repro/serve/README.md) plus schema_version/metrics/spans, the
    trace validates as Perfetto trace_event JSON, and the prom file is
    text exposition format with the serve_* counters."""
    import json

    jpath = tmp_path / "serve.json"
    tpath = tmp_path / "trace.json"
    ppath = tmp_path / "metrics.prom"
    res = run_cli(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--reduced", "--mixed", "--requests", "4", "--batch", "3",
                   "--prompt-len", "16", "--gen", "8",
                   "--json", str(jpath), "--trace", str(tpath),
                   "--prom", str(ppath)])
    assert res.returncode == 0, res.stdout[-400:] + res.stderr[-400:]
    assert "TTFT" in res.stdout and "TPOT" in res.stdout

    payload = json.loads(jpath.read_text())
    from repro.obs import SCHEMA_VERSION

    assert payload["schema_version"] == SCHEMA_VERSION
    legacy = {"arch", "engine", "reserve", "requests", "served", "wall_s",
              "prompt_tokens", "generated_tokens", "tok_per_s", "states",
              "all_terminal", "rejected", "expired", "cancelled", "failed",
              "preemptions", "fault_kills", "resumed_prefills",
              "fault_events", "fault_paused_steps"}
    assert legacy <= set(payload), legacy - set(payload)
    assert payload["served"] == 4 and payload["all_terminal"]
    # new blocks: registry snapshot + span aggregate, consistent with the
    # legacy counters
    assert payload["metrics"]["serve_finished"] == 4
    assert payload["metrics"]["serve_generated_tokens"] == \
        payload["generated_tokens"]
    assert payload["spans"]["requests"] == 4
    assert payload["spans"]["tokens"] == payload["generated_tokens"]
    assert set(payload["spans"]["ttft_s"]) == {"p50", "p90", "p99"}

    from repro.obs import validate_trace_file

    stats = validate_trace_file(str(tpath))
    assert stats["slices"] > 0 and stats["tracks"] >= 2

    prom = ppath.read_text()
    assert "# TYPE serve_finished counter" in prom
    assert "serve_finished 4" in prom
    assert "decode_seconds_bucket" in prom


@pytest.mark.slow
def test_serve_driver_static_mixed():
    res = run_cli(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                   "--reduced", "--engine", "static", "--mixed",
                   "--requests", "4", "--batch", "2", "--prompt-len", "16",
                   "--gen", "8"])
    assert res.returncode == 0, res.stdout[-400:] + res.stderr[-400:]
    assert "served 4 requests" in res.stdout and "tok/s" in res.stdout
