"""MoE StackedExperts on CompactWeight + the batched backend path.

Acceptance: init -> apply -> grad -> checkpoint end-to-end through the
stacked Pallas kernel (interpret mode on CPU), parity against the
masked-dense formulation, and the batched dispatcher across backends.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import MoELayer, StackedExperts
from repro.sparsity import (
    CompactWeight,
    SparsityConfig,
    available_backends,
    dense_weight,
    get_backend,
    sparse_linear_batched,
)
from repro.train.checkpoint import load_pytree, save_pytree

SP_COMPACT = SparsityConfig(pattern="rbgp4", sparsity=0.75, backend="pallas",
                            min_dim=64)


def _ref_apply(params, xe, act=jax.nn.silu):
    wg, wu, wd = (dense_weight(params[k]) for k in ("gate", "up", "down"))
    h = act(jnp.einsum("gecd,ehd->gech", xe, wg))
    h = h * jnp.einsum("gecd,ehd->gech", xe, wu)
    return jnp.einsum("gech,edh->gecd", h, wd)


def test_stacked_experts_compact_storage_and_parity():
    se = StackedExperts(4, 128, 256, SP_COMPACT, act="silu")
    assert se.compact and not se.masked
    params = se.init(jax.random.PRNGKey(0))
    assert isinstance(params["gate"], CompactWeight)
    assert params["gate"].w_data.shape[0] == 4
    # one shared layout across experts (cloned-mask EP)
    assert params["gate"].layout is params["up"].layout

    xe = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8, 128), jnp.float32)
    y = se.apply(params, xe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(_ref_apply(params, xe)),
                               rtol=1e-4, atol=1e-5)


def test_moe_layer_compact_end_to_end():
    """init -> apply -> grad -> checkpoint round trip (+ jit)."""
    moe = MoEConfig(n_experts=4, top_k=2, d_expert=256, capacity_factor=1.25)
    layer = MoELayer(128, moe, SP_COMPACT, act="silu")
    p = layer.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 128), jnp.float32)

    y, aux = layer.apply(p, x)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()

    def loss(p, x):
        y, aux = layer.apply(p, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p, x)
    gd = g["experts"]["gate"].w_data
    assert gd.shape == p["experts"]["gate"].w_data.shape
    assert float(jnp.abs(gd).max()) > 0

    yj, _ = jax.jit(layer.apply)(p, x)
    np.testing.assert_allclose(np.asarray(yj), np.asarray(y),
                               rtol=1e-5, atol=1e-5)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_pytree(path, p)
        p2 = load_pytree(path, p)
    np.testing.assert_array_equal(
        np.asarray(p2["experts"]["down"].w_data),
        np.asarray(p["experts"]["down"].w_data),
    )
    y2, _ = layer.apply(p2, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y),
                               rtol=1e-6, atol=1e-6)


def test_moe_masked_path_unchanged():
    sp = SparsityConfig(pattern="rbgp4", sparsity=0.75, backend="xla_masked",
                        min_dim=64)
    se = StackedExperts(4, 128, 256, sp, act="silu")
    assert se.masked and not se.compact


@pytest.mark.parametrize("backend", ["pallas", "xla_compact", "ref"])
def test_sparse_linear_batched_backend_parity(backend):
    """Every batched-capable backend computes the same stacked projection."""
    from repro.core import RBGP4Layout, RBGP4Spec

    spec = RBGP4Spec(g_o=(4, 4), g_r=(4, 4), g_i=(4, 4), g_b=(1, 1),
                     sp_o=0.5, sp_i=0.5, seed=0)
    lay = RBGP4Layout(spec)
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    w = CompactWeight(
        w_data=jax.random.normal(k1, (3,) + lay.data_shape, jnp.float32),
        layout=lay,
    )
    x = jax.random.normal(k2, (3, 8, lay.k), jnp.float32)
    assert backend in available_backends(batched=True)
    y = sparse_linear_batched(w, x, backend=backend, fuse="relu")
    want = jax.nn.relu(
        jnp.einsum("enk,emk->enm", x, dense_weight(w))
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_sparse_linear_batched_rejects_nonbatched_backend():
    class NoBatch:
        name = "nobatch_test"
        from repro.sparsity import BackendCapabilities
        capabilities = BackendCapabilities()
        accepts = (CompactWeight,)

        def linear(self, weight, x):
            return x

        def matmul(self, weight, x):
            return x

    from repro.sparsity import register_backend
    from repro.sparsity.api import _REGISTRY

    register_backend(NoBatch(), overwrite=True)
    try:
        from repro.core import RBGP4Layout, RBGP4Spec

        spec = RBGP4Spec(g_o=(4, 4), g_r=(4, 4), g_i=(4, 4), g_b=(1, 1),
                         sp_o=0.5, sp_i=0.5, seed=0)
        lay = RBGP4Layout(spec)
        w = CompactWeight(w_data=jnp.zeros((2,) + lay.data_shape), layout=lay)
        x = jnp.zeros((2, 4, lay.k))
        with pytest.raises(NotImplementedError):
            sparse_linear_batched(w, x, backend="nobatch_test")
    finally:
        _REGISTRY.pop("nobatch_test", None)


def test_stacked_experts_unsupported_pattern_names_offender():
    """The NotImplementedError must name the offending pattern and the
    supported set (regression: it used to say only 'rbgp4/dense')."""
    sp = SparsityConfig(pattern="block", sparsity=0.75, backend="xla_masked",
                        min_dim=64)
    with pytest.raises(NotImplementedError) as ei:
        StackedExperts(4, 64, 64, sp)
    msg = str(ei.value)
    assert "'block'" in msg            # the pattern that was passed
    assert "rbgp4" in msg and "dense" in msg   # what is supported
