"""Mesh-sharded serving engines on a forced 4-device CPU mesh.

Each test runs a subprocess that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` *before* importing
jax (the flag is latched at backend init), then checks the acceptance
anchor: greedy tokens from the sharded / disaggregated engines are
bit-identical to the PR 3 ``run_sequential`` oracle **run with the
engine's own sharded params** (``eng.params``).  Sharding a contraction
dim inserts a psum whose ulp-level reduction reorder is chaotically
amplified through network depth, so replicated-vs-sharded comparison is
meaningless — what the serving machinery must guarantee is that paging,
batching, chunking, and role handoff never change bits relative to a
sequential run over the same weight layout.
"""
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PROLOG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np

from repro.configs import apply_sparsity, get_config, reduce_config
from repro.launch.mesh import make_serve_mesh
from repro.models import LMModel
from repro.serve import (
    DisaggregatedEngine,
    ShardedContinuousEngine,
    run_sequential,
)

assert len(jax.devices()) == 4, jax.devices()


def build(arch, backend="xla_masked"):
    cfg = reduce_config(get_config(arch))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5,
                         backend=backend, min_dim=64)
    model = LMModel(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def workload(shapes, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"rid": i, "prompt": rng.integers(0, vocab, s).astype(np.int32),
         "max_new_tokens": g, "sampling": None}
        for i, (s, g) in enumerate(shapes)
    ]


def check_parity(eng, wl, model, tag):
    for r in wl:
        eng.submit(r["prompt"], r["max_new_tokens"])
    out = eng.drain()
    # oracle shares the engine's (sharded) params: exact token replay
    ref = run_sequential(model, eng.params, wl, cache_len=eng.gather_tokens)
    assert set(out) == {r["rid"] for r in wl}, tag
    for r in wl:
        np.testing.assert_array_equal(
            out[r["rid"]], ref[r["rid"]],
            err_msg=f"{tag} request {r['rid']}")
"""


def _run_child(body, timeout=600):
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _PROLOG + body],
                         cwd=_REPO, capture_output=True, text=True,
                         env=env, timeout=timeout)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "SHARDED-SERVE-OK" in res.stdout, res.stdout


def test_sharded_engine_tp_parity():
    """Dense tinyllama on dp=2 x tp=2: non-chunked and chunked prefill
    both replay the sequential oracle token-for-token; chunked never runs
    more than one prefill chunk per step."""
    _run_child(r"""
model, params = build("tinyllama-1.1b")
mesh = make_serve_mesh(2, 2)
wl = workload([(4, 3), (12, 6), (8, 2), (16, 4)], model.cfg.vocab_size)

eng = ShardedContinuousEngine(model, params, mesh, page_size=4,
                              max_slots=3, max_request_len=40)
check_parity(eng, wl, model, "tp-sharded")

eng2 = ShardedContinuousEngine(model, params, mesh, page_size=4,
                               max_slots=3, max_request_len=40,
                               prefill_chunk=5)
check_parity(eng2, wl, model, "tp-sharded-chunked")
assert eng2.stats["prefill_chunks"] == sum(
    -(-r["prompt"].shape[0] // 5) for r in wl)
# decode is never stalled by more than one prefill chunk per step
assert eng2.step_trace
assert all(t["prefill_chunks"] <= 1 for t in eng2.step_trace)
assert any(t["prefill_chunks"] == 1 and t["decode_rows"] > 0
           for t in eng2.step_trace)
print("SHARDED-SERVE-OK")
""")


def test_sharded_engine_tp_ep_moe_parity():
    """MoE (qwen2-moe reduced: 8 experts top-2 + 1 shared) on a tp=2 x
    ep=2 'model' axis: experts shard over the same axis as heads, page
    pools shard on the true heads dim, parity holds."""
    _run_child(r"""
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import page_pool_specs

model, params = build("qwen2-moe-a2.7b")
mesh = make_serve_mesh(1, 2, 2)   # 'model' axis = tp * ep = 4

eng = ShardedContinuousEngine(model, params, mesh, page_size=4,
                              max_slots=2, max_request_len=32)
# the pools shard on the heads dim (and only there): blocks replicated so
# any decode row can read any block
specs = page_pool_specs(eng.kv.pools, mesh)
found_model = []
for leaf in jax.tree_util.tree_leaves(specs):
    spec = tuple(leaf.spec)
    assert all(s in (None, "model") for s in spec), spec
    if "model" in spec:
        found_model.append(spec)
        assert spec[0] is None, spec  # leading (block/scan) dim replicated
assert found_model, "no pool leaf sharded over 'model'"

wl = workload([(4, 3), (8, 4), (6, 2)], model.cfg.vocab_size, seed=2)
check_parity(eng, wl, model, "tp-ep-moe")
print("SHARDED-SERVE-OK")
""")


def test_sharded_preemption_parity_and_victim_trace():
    """Preemption under pool pressure on the sharded engine: the tight
    pool forces >= 2 mid-generation evictions, outputs still replay the
    sequential oracle bit-for-bit, and the (clock, rid, kind) eviction
    trace — victim choice is host-side, keyed (priority, arrival, rid) —
    is identical across mesh shapes."""
    _run_child(r"""
model, params = build("tinyllama-1.1b")
wl = workload([(4, 8), (12, 10), (8, 9), (16, 6), (6, 10)],
              model.cfg.vocab_size)
kw = dict(page_size=4, max_slots=4, max_request_len=40,
          reserve="prompt", n_blocks=11)

traces = {}
for tag, axes in (("dp2-tp2", (2, 2)), ("tp4", (1, 4)), ("dp4", (4, 1))):
    eng = ShardedContinuousEngine(model, params, make_serve_mesh(*axes),
                                  **kw)
    check_parity(eng, wl, model, f"preempt-{tag}")
    assert eng.stats["preemptions"] >= 2, (tag, eng.stats)
    assert eng.stats["resumed_prefills"] >= 2, (tag, eng.stats)
    alloc = eng.kv.allocator
    assert alloc.n_allocated == 0 and alloc.n_free == alloc.n_total, tag
    traces[tag] = list(eng.preempt_log)

# deterministic victim ordering: TP x EP preempts identically regardless
# of how the mesh is carved up
assert traces["dp2-tp2"] == traces["tp4"] == traces["dp4"], traces
print("SHARDED-SERVE-OK")
""")


def test_disaggregated_engine_parity_and_handoff():
    """Prefill/decode roles on disjoint 2-device submeshes: every request
    crosses one explicit KV-page handoff and still replays the oracle."""
    _run_child(r"""
devs = jax.devices()
prefill_mesh = make_serve_mesh(1, 2, devices=devs[:2])
decode_mesh = make_serve_mesh(1, 2, devices=devs[2:])

model, params = build("tinyllama-1.1b")
wl = workload([(4, 3), (12, 6), (8, 2), (16, 4)], model.cfg.vocab_size,
              seed=1)

eng = DisaggregatedEngine(model, params, decode_mesh, prefill_mesh,
                          page_size=4, max_slots=3, max_request_len=40)
check_parity(eng, wl, model, "disagg")
assert eng.stats["handoffs"] == len(wl), eng.stats["handoffs"]

# chunked prefill on the prefill role: the handoff still happens once per
# request, after the last chunk
eng2 = DisaggregatedEngine(model, params, decode_mesh, prefill_mesh,
                           page_size=4, max_slots=3, max_request_len=40,
                           prefill_chunk=5)
check_parity(eng2, wl, model, "disagg-chunked")
assert eng2.stats["handoffs"] == len(wl)
assert all(t["prefill_chunks"] <= 1 for t in eng2.step_trace)
print("SHARDED-SERVE-OK")
""")


def test_sharded_prefix_sharing_parity():
    """Prefix sharing on the mesh engines: shared-prefix workload (COW +
    partial hits) replays the oracle bit-for-bit on the TP-sharded engine
    and across the disaggregation boundary — the gathered prefix is read
    from decode-role pools, localized (host round-trip, bits only), and
    the suffix chunk runs on the prefill mesh."""
    _run_child(r"""
import os
os.environ["REPRO_SERVE_CHECKS"] = "1"
model, params = build("tinyllama-1.1b")
rng = np.random.default_rng(0)
V = model.cfg.vocab_size
base = rng.integers(1, V, size=16).astype(np.int32)
tail = rng.integers(1, V, size=9).astype(np.int32)
wl = [
    {"rid": 0, "prompt": base.copy(), "max_new_tokens": 4},
    {"rid": 1, "prompt": base.copy(), "max_new_tokens": 4},
    {"rid": 2, "prompt": base[:8].copy(), "max_new_tokens": 4},
    {"rid": 3, "prompt": np.concatenate([base[:12], tail]),
     "max_new_tokens": 4},
]

mesh = make_serve_mesh(2, 2)
for chunk in (0, 5):
    eng = ShardedContinuousEngine(model, params, mesh, page_size=4,
                                  max_slots=1, max_request_len=32,
                                  prefill_chunk=chunk, prefix_cache=True)
    check_parity(eng, wl, model, f"sharded-prefix-chunk{chunk}")
    assert eng.stats["prefix_hits"] > 0, eng.stats
    assert eng.stats["prefix_cow_copies"] >= 2, eng.stats
    eng.kv.allocator.check_invariants()

devs = jax.devices()
prefill_mesh = make_serve_mesh(1, 2, devices=devs[:2])
decode_mesh = make_serve_mesh(1, 2, devices=devs[2:])
for chunk in (0, 5):
    eng = DisaggregatedEngine(model, params, decode_mesh, prefill_mesh,
                              page_size=4, max_slots=1, max_request_len=32,
                              prefill_chunk=chunk, prefix_cache=True)
    check_parity(eng, wl, model, f"disagg-prefix-chunk{chunk}")
    assert eng.stats["prefix_hits"] > 0, eng.stats
    assert eng.stats["shared_prefills"] > 0, eng.stats
    eng.kv.allocator.check_invariants()
print("SHARDED-SERVE-OK")
""")
