"""Tests for RBGP4 spec, layout, compact pack/unpack, transpose, designer."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import RBGP4Layout, RBGP4Spec, design_rbgp4
from repro.core.rbgp import pow2_sparsity_steps


def small_spec(sp_o=0.5, sp_i=0.5, seed=0):
    return RBGP4Spec(
        g_o=(4, 4), g_r=(2, 2), g_i=(4, 4), g_b=(2, 2),
        sp_o=sp_o, sp_i=sp_i, seed=seed,
    )


def test_spec_sizes():
    sp = small_spec()
    assert sp.m == 4 * 2 * 4 * 2 == 64
    assert sp.k == 64
    assert sp.tile_m == 16 and sp.tile_k == 16
    assert sp.group_rows == 4 and sp.chunk_cols == 4
    assert sp.d_o == 2 and sp.d_i == 2
    assert abs(sp.sparsity - 0.75) < 1e-12
    assert sp.nnz_per_row == 2 * 2 * 4 == 16
    assert sp.nnz == 64 * 16


def test_pow2_sparsity_steps():
    assert pow2_sparsity_steps(0.0) == 0
    assert pow2_sparsity_steps(0.5) == 1
    assert pow2_sparsity_steps(0.9375) == 4
    with pytest.raises(ValueError):
        pow2_sparsity_steps(0.6)


@pytest.mark.parametrize("sp_o,sp_i", [(0.0, 0.5), (0.5, 0.0), (0.5, 0.5), (0.75, 0.5)])
def test_mask_matches_kron_structure(sp_o, sp_i):
    spec = RBGP4Spec(g_o=(8, 8), g_r=(2, 2), g_i=(4, 4), g_b=(2, 2),
                     sp_o=sp_o, sp_i=sp_i, seed=1)
    lay = RBGP4Layout(spec)
    mask = lay.mask()
    assert mask.shape == (spec.m, spec.k)
    # i-major ordering: mask = kron(BA_o, kron(BA_i, ones(G, C)))
    expect = np.kron(
        lay.graph_o.biadjacency,
        np.kron(lay.graph_i.biadjacency,
                np.ones((spec.group_rows, spec.chunk_cols), np.uint8)),
    )
    assert (mask == expect).all()
    # row-uniform nnz
    assert (mask.sum(axis=1) == spec.nnz_per_row).all()
    # isomorphic to the paper-order product: same total edges & spectra sizes
    paper = lay.paper_order_structure()
    assert paper.n_edges == int(mask.sum())


def test_pack_unpack_roundtrip():
    lay = RBGP4Layout(small_spec())
    rng = np.random.default_rng(0)
    w = rng.standard_normal((lay.m, lay.k)).astype(np.float32)
    mask = lay.mask().astype(np.float32)
    wm = w * mask
    data = lay.pack(wm)
    assert data.shape == lay.data_shape
    back = lay.unpack(data)
    assert np.array_equal(back, wm)
    # pack ignores off-mask values
    assert np.array_equal(lay.pack(w), data)


def test_transpose_layout_and_perm():
    lay = RBGP4Layout(small_spec(seed=3))
    lt = lay.transpose_layout()
    assert (lt.mask() == lay.mask().T).all()
    rng = np.random.default_rng(1)
    w = rng.standard_normal((lay.m, lay.k)).astype(np.float32) * lay.mask()
    data = lay.pack(w)
    perm = lay.transpose_perm()
    data_t = data.ravel()[perm].reshape(lt.data_shape)
    assert np.array_equal(lt.unpack(data_t), w.T)


def test_memory_accounting():
    lay = RBGP4Layout(small_spec())
    mem = lay.memory_bytes(value_bytes=4, index_bytes=4)
    assert mem["values"] == lay.spec.nnz * 4
    assert mem["index_succinct"] < mem["index_full"]
    assert mem["index_compression"] > 1


@pytest.mark.parametrize(
    "m,k,sp",
    [
        (4096, 4096, 0.75),
        (24576, 3072, 0.5),     # gemma-7b ffn
        (11008, 4096, 0.875),   # deepseek-7b ffn (odd factor 43)
        (5632, 2048, 0.9375),   # tinyllama ffn (odd factor 11)
        (1408, 2048, 0.75),     # qwen2-moe expert
        (1536, 5120, 0.5),      # deepseek-v2 expert
        (256, 256, 0.5),
    ],
)
def test_designer_feasible_shapes(m, k, sp):
    spec = design_rbgp4(m, k, sp)
    assert spec.m == m and spec.k == k
    assert abs(spec.sparsity - sp) < 1e-9
    spec.validate()
    # MXU-friendliness where the shape allows it
    if m % 128 == 0:
        assert spec.tile_m >= 64
    lay = RBGP4Layout(spec)
    assert lay.adj_o.shape == (spec.g_o[0], spec.d_o)
    assert lay.adj_i.shape == (spec.g_i[0], spec.d_i)


@given(
    mexp=st.integers(7, 11),
    kexp=st.integers(7, 11),
    kstep=st.integers(1, 4),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_designer_property(mexp, kexp, kstep, seed):
    m, k = 2**mexp, 2**kexp
    sp = 1.0 - 2.0**-kstep
    spec = design_rbgp4(m, k, sp, seed=seed)
    assert spec.m == m and spec.k == k
    assert abs(spec.sparsity - sp) < 1e-9
    lay = RBGP4Layout(spec)
    mask = lay.mask()
    nnz = int(mask.sum())
    assert nnz == spec.nnz
    assert abs(1 - nnz / (m * k) - sp) < 1e-9
