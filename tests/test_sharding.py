"""Unit tests for the sharding rules and activation constraints (8 fake
devices; the 512-device production meshes are exercised by launch/dryrun)."""
import os
import subprocess
import sys

import numpy as np
import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import param_spec, dp_axes, cache_specs
from repro.parallel.constrain import activation_mesh, shard

try:
    from jax.sharding import AxisType
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
except ImportError:  # older jax: mesh axes are implicitly Auto
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

# -- param rules --------------------------------------------------------------
assert dp_axes(mesh) == ("pod", "data")
cases = {
    # path, shape -> expected spec
    ("params/embed/0/embedding", (64, 32)): P("model", ("pod", "data")),
    ("params/stack/head/0/mixer/wq/w", (32, 16)): P("model", ("pod", "data")),
    ("params/stack/head/0/mixer/wo/w", (16, 32)): P(("pod", "data"), "model"),
    ("params/stack/head/0/ffn/down/w_data", (16, 8)): P(("pod", "data"), "model"),
    ("params/stack/head/0/norm1/scale", (16,)): P(None),
    ("params/stack/head/0/ffn/_ba_o", (4, 4)): P(None, None),
    ("params/stack/head/0/ffn/moe/router", (8, 16)): P(None, None),
    ("m/stack/scan/j0/ffn/experts/gate", (2, 8, 16, 32)):
        P(None, "model", None, ("pod", "data")),  # scanned: leading dim None
    ("params/stack/head/0/mixer/wk_b", (8, 16, 4)): P("model", None, None),
}
for (path, shape), want in cases.items():
    got = param_spec(path, shape, mesh)
    assert tuple(got) == tuple(want), (path, got, want)
# indivisible dims are never sharded
got = param_spec("x/wq/w", (33, 17), mesh)
assert tuple(got) == (None, None), got

# -- cache specs: stacked scan caches shift dims by one -----------------------
cache = {"scan": {"j0": {"k": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), jnp.bfloat16),
                          "pos": jax.ShapeDtypeStruct((4, 8, 64), jnp.int32)}},
         "head": [{"k": jax.ShapeDtypeStruct((8, 64, 2, 16), jnp.bfloat16)}],
         "tail": []}
specs = cache_specs(cache, mesh, long_context=False)
sc = specs["scan"]["j0"]["k"].spec
assert sc[0] is None and sc[1] == ("pod", "data"), sc  # layer dim unsharded
hd = specs["head"][0]["k"].spec
assert hd[0] == ("pod", "data"), hd

# -- activation constraints ----------------------------------------------------
with activation_mesh(mesh):
    x = jnp.ones((8, 4, 16))
    y = jax.jit(lambda x: shard(x, "dp", None, "tp"))(x)
    s = y.sharding.spec
    assert s[0] == ("pod", "data") and s[2] == "model", s
    # indivisible dims dropped silently
    z = jax.jit(lambda x: shard(x, "dp", "tp", None))(jnp.ones((8, 3, 4)))
    assert "model" not in jax.tree_util.tree_leaves(tuple(z.sharding.spec))
# no-op without a mesh
out = shard(jnp.ones((4,)), "dp")
assert isinstance(out, jax.Array)
print("SHARDING-OK")
"""


def test_sharding_rules_under_fake_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", CHILD], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env, timeout=300)
    assert "SHARDING-OK" in res.stdout, res.stdout + res.stderr
