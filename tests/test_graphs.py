"""Property tests for bipartite graph generation (paper App. 8.1)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BipartiteGraph,
    complete_bipartite,
    generate_biregular,
    generate_ramanujan,
    is_ramanujan,
    second_singular_value,
    two_lift,
)

sides = st.integers(min_value=1, max_value=8)
lifts = st.integers(min_value=0, max_value=4)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@given(nl=sides, nr=sides)
def test_complete_bipartite_props(nl, nr):
    g = complete_bipartite(nl, nr)
    assert g.n_edges == nl * nr
    assert g.is_biregular
    assert g.d_left == nr and g.d_right == nl
    assert g.sparsity == 0.0
    assert g.is_complete


@given(nl=sides, nr=sides, n=lifts, seed=seeds)
@settings(max_examples=60, deadline=None)
def test_two_lift_preserves_biregularity(nl, nr, n, seed):
    rng = np.random.default_rng(seed)
    g = complete_bipartite(nl, nr)
    d_l, d_r = g.d_left, g.d_right
    for _ in range(n):
        g = two_lift(g, rng)
    assert g.n_left == nl * 2**n and g.n_right == nr * 2**n
    assert g.n_edges == nl * nr * 2**n
    assert g.is_biregular
    # 2-lift preserves degrees exactly
    assert g.d_left == d_l and g.d_right == d_r


@given(seed=seeds)
@settings(max_examples=20, deadline=None)
def test_two_lift_edge_partition(seed):
    """Each lifted edge pair is either parallel or crossed, never both."""
    rng = np.random.default_rng(seed)
    g = complete_bipartite(3, 4)
    gl = two_lift(g, rng)
    ba = gl.biadjacency
    nl, nr = 3, 4
    a, b = ba[:nl, :nr], ba[nl:, nr:]
    c, d = ba[:nl, nr:], ba[nl:, :nr]
    assert (a == b).all() and (c == d).all()
    assert ((a + c) == g.biadjacency).all()


@pytest.mark.parametrize(
    "nl,nr,sp",
    [(8, 8, 0.5), (16, 8, 0.75), (32, 32, 0.875), (16, 64, 0.9375), (12, 12, 0.5)],
)
def test_generate_biregular_sizes(nl, nr, sp):
    g = generate_biregular(nl, nr, sp, np.random.default_rng(0))
    assert (g.n_left, g.n_right) == (nl, nr)
    assert abs(g.sparsity - sp) < 1e-9
    assert g.is_biregular
    assert g.d_left == round((1 - sp) * nr)


def test_generate_biregular_rejects_bad_sparsity():
    with pytest.raises(ValueError):
        generate_biregular(8, 8, 0.6, np.random.default_rng(0))
    with pytest.raises(ValueError):
        generate_biregular(10, 10, 0.75, np.random.default_rng(0))  # 2.5 base


@pytest.mark.parametrize("nl,nr,sp", [(16, 16, 0.5), (32, 16, 0.75), (64, 64, 0.875)])
def test_generate_ramanujan_is_ramanujan(nl, nr, sp):
    g = generate_ramanujan(nl, nr, sp, seed=3)
    assert (g.n_left, g.n_right) == (nl, nr)
    assert is_ramanujan(g)
    bound = math.sqrt(g.d_left - 1) + math.sqrt(g.d_right - 1)
    assert second_singular_value(g) <= bound + 1e-9


def test_complete_is_trivially_ramanujan():
    assert is_ramanujan(complete_bipartite(4, 8))
    # lambda_2 of complete bipartite is 0
    assert second_singular_value(complete_bipartite(4, 8)) < 1e-9


def test_adjacency_roundtrip():
    g = generate_ramanujan(16, 8, 0.5, seed=0)
    adj = g.left_adjacency()
    assert adj.shape == (16, g.d_left)
    rebuilt = np.zeros_like(g.biadjacency)
    for u in range(16):
        rebuilt[u, adj[u]] = 1
    assert (rebuilt == g.biadjacency).all()
    # transpose adjacency consistency
    adj_t = g.right_adjacency()
    assert adj_t.shape == (8, g.d_right)
    rebuilt_t = np.zeros((8, 16), dtype=np.uint8)
    for v in range(8):
        rebuilt_t[v, adj_t[v]] = 1
    assert (rebuilt_t == g.biadjacency.T).all()
