"""Train substrate tests: optimizers, schedules, loop, checkpoint/restart,
distillation, gradient compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, reduce_config
from repro.data import GaussianClassImages, Prefetcher, TokenStream, host_shard
from repro.models import LMModel
from repro.train import (
    CheckpointManager,
    Trainer,
    distillation_loss,
    init_train_state,
    kd_loss,
    make_schedule,
    make_train_step,
    quantize_int8,
    dequantize_int8,
)
from repro.utils import merge_trees


def tiny_model():
    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = cfg.with_(n_layers=2, vocab_size=64)
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(full, batch):
        loss, (ce, aux) = model.loss(full, batch)
        return loss, {"ce": ce}

    return cfg, model, params, loss_fn


def data_for(cfg, batch=4, seq=16):
    return TokenStream(cfg.vocab_size, batch, seq, seed=3)


def test_schedules():
    cos = make_schedule(TrainConfig(schedule="cosine", lr=1.0, warmup_steps=10,
                                    total_steps=100))
    assert float(cos(jnp.int32(0))) == 0.0
    assert abs(float(cos(jnp.int32(10))) - 1.0) < 1e-6
    assert float(cos(jnp.int32(100))) < 1e-6
    step = make_schedule(TrainConfig(schedule="step", lr=1.0,
                                     lr_step_epochs=(5, 10), lr_step_gamma=0.1))
    assert abs(float(step(jnp.int32(0))) - 1.0) < 1e-6
    assert abs(float(step(jnp.int32(7))) - 0.1) < 1e-6
    assert abs(float(step(jnp.int32(12))) - 0.01) < 1e-6


@pytest.mark.parametrize("opt", ["sgdm", "adamw"])
def test_loss_decreases(opt, tmp_path):
    cfg, model, params, loss_fn = tiny_model()
    tcfg = TrainConfig(optimizer=opt, lr=0.05 if opt == "sgdm" else 1e-3,
                       schedule="constant", grad_clip=1.0,
                       checkpoint_dir=str(tmp_path), checkpoint_every=1000)
    tr = Trainer(loss_fn, params, tcfg, data_for(cfg), checkpoint=False)
    hist = tr.run(30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_microbatch_accumulation_matches_full_batch():
    cfg, model, params, loss_fn = tiny_model()
    data = data_for(cfg, batch=8)
    batch = jax.tree_util.tree_map(jnp.asarray, next(iter(data)))

    tcfg1 = TrainConfig(optimizer="sgdm", lr=0.1, schedule="constant",
                        grad_clip=0.0, microbatches=1)
    tcfg4 = TrainConfig(optimizer="sgdm", lr=0.1, schedule="constant",
                        grad_clip=0.0, microbatches=4)
    s1 = init_train_state(params, tcfg1)
    s4 = init_train_state(params, tcfg4)
    step1 = make_train_step(loss_fn, tcfg1)
    step4 = make_train_step(loss_fn, tcfg4)

    s1n, m1 = step1(s1, batch)
    mb = jax.tree_util.tree_map(
        lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    s4n, m4 = step4(s4, mb)
    # microbatch losses average over chunks; grads average -> same update up
    # to accumulation-order float error
    for a, b in zip(jax.tree_util.tree_leaves(s1n.params),
                    jax.tree_util.tree_leaves(s4n.params)):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_checkpoint_restart_resumes_identically(tmp_path):
    cfg, model, params, loss_fn = tiny_model()
    tcfg = TrainConfig(optimizer="sgdm", lr=0.05, schedule="constant",
                       checkpoint_dir=str(tmp_path), checkpoint_every=5)
    # run 10 steps, checkpointing every 5
    tr = Trainer(loss_fn, params, tcfg, data_for(cfg))
    tr.run(10)
    # fresh trainer resumes from step 10
    tr2 = Trainer(loss_fn, params, tcfg, data_for(cfg))
    resumed = tr2.try_resume()
    assert resumed == 10
    for a, b in zip(jax.tree_util.tree_leaves(tr.state.params),
                    jax.tree_util.tree_leaves(tr2.state.params)):
        if a is None:
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_simulated_failure_then_restart(tmp_path):
    cfg, model, params, loss_fn = tiny_model()
    tcfg = TrainConfig(optimizer="sgdm", lr=0.05, schedule="constant",
                       checkpoint_dir=str(tmp_path), checkpoint_every=4)
    tr = Trainer(loss_fn, params, tcfg, data_for(cfg))
    with pytest.raises(RuntimeError, match="simulated node failure"):
        tr.run(20, fail_at_step=9)
    # new process: auto-resume from the last checkpoint (step 8)
    tr2 = Trainer(loss_fn, params, tcfg, data_for(cfg))
    assert tr2.try_resume() == 8
    tr2.run(4)
    assert int(tr2.state.step) == 12


def test_legacy_checkpoint_keys_restore_into_containers(tmp_path):
    """Pre-container .npz snapshots restore into the new weight pytrees."""
    from repro.sparsity import SparseLinear, SparsityConfig
    from repro.train.checkpoint import load_pytree, save_pytree

    lin = SparseLinear(64, 64, SparsityConfig(pattern="rbgp4", sparsity=0.5,
                                              backend="xla_masked", min_dim=1))
    w = lin.init(jax.random.PRNGKey(0))
    legacy = {"layer": {"w": np.asarray(w.w), "_ba_o": np.asarray(w.ba_o),
                        "_ba_i": np.asarray(w.ba_i)},
              "experts": {"gate": np.ones((2, 4, 4), np.float32)}}
    p = str(tmp_path / "legacy.npz")
    save_pytree(p, legacy)
    # restore into the container-shaped structure the new code builds
    import dataclasses as dc
    like = {"layer": w,
            "experts": {"gate": dc.replace(w, w=jnp.zeros((2, 4, 4)),
                                           ba_o=None, ba_i=None)}}
    got = load_pytree(p, like)
    np.testing.assert_array_equal(np.asarray(got["layer"].w), np.asarray(w.w))
    np.testing.assert_array_equal(np.asarray(got["layer"].ba_o),
                                  np.asarray(w.ba_o))
    np.testing.assert_array_equal(np.asarray(got["experts"]["gate"].w),
                                  np.ones((2, 4, 4), np.float32))


def test_legacy_moe_factor_keys_restore_into_containers(tmp_path):
    """Old experts/_ba_*_{in,out} keys restore into per-container factors."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import StackedExperts
    from repro.sparsity import SparsityConfig
    from repro.train.checkpoint import load_pytree, save_pytree

    sp = SparsityConfig(pattern="rbgp4", sparsity=0.5, backend="xla_masked",
                        min_dim=1)
    ex = StackedExperts(2, 64, 64, sp)
    new = ex.init(jax.random.PRNGKey(0))
    legacy = {"experts": {
        "gate": np.asarray(new["gate"].w), "up": np.asarray(new["up"].w),
        "down": np.asarray(new["down"].w),
        "_ba_o_in": np.asarray(new["gate"].ba_o),
        "_ba_i_in": np.asarray(new["gate"].ba_i),
        "_ba_o_out": np.asarray(new["down"].ba_o),
        "_ba_i_out": np.asarray(new["down"].ba_i),
    }}
    p = str(tmp_path / "legacy_moe.npz")
    save_pytree(p, legacy)
    got = load_pytree(p, {"experts": new})
    for name in ("gate", "up", "down"):
        np.testing.assert_array_equal(np.asarray(got["experts"][name].w),
                                      np.asarray(new[name].w))
        np.testing.assert_array_equal(np.asarray(got["experts"][name].ba_o),
                                      np.asarray(new[name].ba_o))


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.steps() == [2, 3]  # retention
    got, meta = mgr.restore(tree)
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5.0))
    # no stray tmp files
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_kd_loss_properties():
    s = jnp.asarray([[2.0, 0.0, -2.0]])
    assert float(kd_loss(s, s)) < 1e-9  # zero at teacher==student
    t = jnp.asarray([[0.0, 2.0, -1.0]])
    assert float(kd_loss(s, t)) > 0.0
    hard = jnp.float32(1.0)
    mixed = distillation_loss(s, t, hard, alpha=0.5)
    assert float(mixed) != float(hard)
    assert float(distillation_loss(s, t, hard, alpha=0.0)) == 1.0


def test_int8_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x)).max()
    assert err <= float(scale) * 0.51


def test_int8_compression_training_converges(tmp_path):
    cfg, model, params, loss_fn = tiny_model()
    tcfg = TrainConfig(optimizer="sgdm", lr=0.05, schedule="constant",
                       grad_compression="int8", checkpoint_dir=str(tmp_path))
    tr = Trainer(loss_fn, params, tcfg, data_for(cfg), checkpoint=False)
    hist = tr.run(30)
    assert np.mean([h["loss"] for h in hist[-5:]]) < \
        np.mean([h["loss"] for h in hist[:5]]) - 0.05


def test_token_stream_determinism_and_learnability():
    ts = TokenStream(64, 4, 32, seed=1)
    a, b = ts.batch_at(7), ts.batch_at(7)
    np.testing.assert_array_equal(a, b)
    # low-entropy: the affine recurrence makes successor deterministic >90%
    t = ts.batch_at(0)
    succ_match = np.mean(t[:, 1:] == (ts.a * t[:, :-1] + ts.c) % 64)
    assert succ_match > 0.85


def test_prefetcher_and_host_shard():
    ts = TokenStream(64, 4, 8, seed=0)
    pf = Prefetcher(ts, depth=2)
    b1 = next(pf)
    assert b1["tokens"].shape == (4, 8)
    start, size = host_shard(256, process_index=0, process_count=1)
    assert (start, size) == (0, 256)
    start, size = host_shard(256, process_index=3, process_count=8)
    assert (start, size) == (96, 32)


def test_vision_data():
    g = GaussianClassImages(10, 8, seed=0)
    b = g.batch_at(0)
    assert b["images"].shape == (8, 32, 32, 3)
    assert b["labels"].shape == (8,)
    np.testing.assert_array_equal(b["images"], g.batch_at(0)["images"])
