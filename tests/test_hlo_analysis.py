"""Tests for the trip-count-aware HLO analyzer (the roofline instrument)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo


def cost(f, *specs):
    return analyze_hlo(jax.jit(f).lower(*specs).compile().as_text())


def test_plain_matmul_exact():
    a = cost(lambda x, w: x @ w,
             jax.ShapeDtypeStruct((128, 256), jnp.float32),
             jax.ShapeDtypeStruct((256, 512), jnp.float32))
    assert abs(a.dot_flops - 2 * 128 * 256 * 512) < 1


@pytest.mark.parametrize("n", [1, 10, 22])
def test_scan_trip_count_multiplies(n):
    def g(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    a = cost(g, jax.ShapeDtypeStruct((128, 256), jnp.float32),
             jax.ShapeDtypeStruct((n, 256, 256), jnp.float32))
    assert abs(a.dot_flops - 2 * 128 * 256 * 256 * n) < 1
    assert a.unknown_trip_counts == 0


def test_nested_scan():
    def h(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    a = cost(h, jax.ShapeDtypeStruct((64, 64), jnp.float32),
             jax.ShapeDtypeStruct((3, 64, 64), jnp.float32))
    assert abs(a.dot_flops - 2 * 64 * 64 * 64 * 15) < 1


def test_grad_through_scan():
    def g(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    a = cost(jax.grad(g, argnums=1),
             jax.ShapeDtypeStruct((128, 256), jnp.float32),
             jax.ShapeDtypeStruct((10, 256, 256), jnp.float32))
    want = 2 * 128 * 256 * 256 * 10 * 3  # fwd + 2 bwd matmuls per step
    assert abs(a.dot_flops - want) / want < 0.01


def test_scan_residual_bytes_not_full_stack():
    """The backward slices stacked residuals; bytes must reflect the slice,
    not the whole (T, ...) array per iteration (the bug that inflated SSM
    cells 100x before the effective-bytes fix)."""
    T, D = 64, 128

    def g(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    a = cost(jax.grad(g, argnums=1),
             jax.ShapeDtypeStruct((8, D), jnp.float32),
             jax.ShapeDtypeStruct((T, D, D), jnp.float32))
    # generous bound: per step a few (8,D)+(D,D) tensors; full-stack
    # counting would be ~T*T*D*D*4 ~ 17 GB
    assert a.bytes_accessed < 1e9, f"bytes {a.bytes_accessed:.2e} look inflated"


def test_collectives_counted_with_factors():
    import os

    if jax.device_count() < 4:
        pytest.skip("needs forced multi-device runtime")
    # (covered implicitly by dry-run integration; unit check via psum)


def test_collective_bytes_psum():
    # single-device: no collectives
    a = cost(lambda x: x * 2, jax.ShapeDtypeStruct((128,), jnp.float32))
    assert a.total_collective_bytes == 0
