"""Model zoo tests: every family forward/loss/decode + cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
)
from repro.models import LMModel, VGG19, VisionConfig, WideResNet
from repro.sparsity import SparsityConfig

SP = SparsityConfig(pattern="rbgp4", sparsity=0.5, backend="xla_masked", min_dim=32)
BASE = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=53, max_seq_len=64, sparsity=SP, compute_dtype="float32",
)


def _cfg(**kw):
    merged = {**BASE, **kw}
    return ModelConfig(name="t", family=kw.get("family", "dense"), **{
        k: v for k, v in merged.items() if k != "family"
    })


FAMILY_CONFIGS = {
    "dense": _cfg(),
    "swa": _cfg(layer_pattern=("swa", "swa", "attn"), sliding_window=8),
    "moe": _cfg(moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=64,
                              every_n_layers=2, first_dense=1)),
    "mla": _cfg(layer_pattern=("mla",),
                mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              rope_head_dim=8, nope_head_dim=16, v_head_dim=16)),
    "hybrid": _cfg(layer_pattern=("mamba", "mamba", "attn"), n_layers=6,
                   mamba=MambaConfig(d_state=4),
                   # capacity_factor sized for no token drops so the
                   # decode-vs-forward consistency check is exact
                   moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                 every_n_layers=2, capacity_factor=8.0)),
    "rwkv": _cfg(layer_pattern=("rwkv",),
                 rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=8)),
    "audio": _cfg(n_codebooks=4),
    "vlm": _cfg(frontend="vision", n_patches=4),
}


def _batch(cfg, B=2, S=16, key=1):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(key), shape, 0,
                                     cfg.vocab_size)
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_patches, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("family", list(FAMILY_CONFIGS))
def test_forward_loss_no_nans(family):
    cfg = FAMILY_CONFIGS[family]
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch, train=True)
    exp = (2, 16, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 \
        else (2, 16, cfg.vocab_size)
    assert logits.shape == exp
    assert not bool(jnp.isnan(logits).any())
    loss, (ce, aux2) = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert 2.0 < float(ce) < 8.0  # ~ln(53) for random init


@pytest.mark.parametrize("family", ["dense", "swa", "mla", "hybrid", "rwkv"])
def test_decode_matches_forward(family):
    cfg = FAMILY_CONFIGS[family]
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, 24, jnp.float32)
    lg, cache = model.prefill(params, {"tokens": toks[:, :6]}, cache)
    errs = [float(jnp.abs(lg - full[:, 5]).max())]
    for t in range(6, S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4, errs


def test_grad_flows_everywhere():
    cfg = FAMILY_CONFIGS["dense"]
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.utils import merge_trees, split_trainable

    train, static = split_trainable(params)
    batch = _batch(cfg)
    g = jax.grad(
        lambda t: model.loss(merge_trees(t, static), batch)[0]
    )(train)
    norms = [
        float(jnp.abs(x).max()) for x in jax.tree_util.tree_leaves(g)
        if x is not None
    ]
    assert all(np.isfinite(n) for n in norms)
    assert sum(n > 0 for n in norms) > len(norms) * 0.9


def test_scan_stack_structure():
    """gemma3-like 5:1 pattern with a non-divisible layer count."""
    cfg = _cfg(n_layers=34 % 10 + 10,  # 14 layers
               layer_pattern=("swa", "swa", "attn"))
    model = LMModel(cfg)
    st = model.stack
    assert st.n_head == 0
    assert st.period == 3
    assert st.n_full == 4
    assert len(st.tail_layers) == 2
    params = model.init(jax.random.PRNGKey(0))
    # scanned params stacked with leading dim n_full
    leaves = jax.tree_util.tree_leaves(params["stack"]["scan"])
    assert all(l.shape[0] == 4 for l in leaves)
    logits, _ = model.forward(params, _batch(cfg))
    assert not bool(jnp.isnan(logits).any())


def test_first_dense_moe_head_split():
    """deepseek-v2-like: layer 0 dense MLP, rest MoE -> head=1."""
    cfg = _cfg(moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                             every_n_layers=1, first_dense=1))
    st = LMModel(cfg).stack
    assert st.n_head == 1
    assert st.n_full == 3 and st.period == 1


@pytest.mark.parametrize("cls", [VGG19, WideResNet])
def test_vision_models(cls):
    vcfg = VisionConfig(
        name="v", n_classes=10,
        sparsity=SparsityConfig(pattern="rbgp4", sparsity=0.5,
                                backend="xla_masked", min_dim=64),
        depth=10, width=1,
    )
    model = cls(vcfg)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    logits = model.apply(params, x, train=True)
    assert logits.shape == (4, 10)
    assert not bool(jnp.isnan(logits).any())
