"""Gradient compression Q/DQ + error feedback (train/compress.py).

PR 9 generalized ``quantize_int8``/``dequantize_int8`` with an ``axis=``
block reduction for the weight-storage path; the gradient wire format
(scalar per-tensor scale) must stay *bit-identical* to the historical
behavior, and the error-feedback recursion must keep its telescoping
guarantee — the long-run mean of dequantized gradients converges to the
true gradient even though each step quantizes coarsely.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compress import (
    compress_decompress,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


def _legacy_qdq(x):
    """The pre-axis= formula, inlined: the regression oracle."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale, (q.astype(jnp.float32) * scale)


# ---------------------------------------------------------------------------
# Q/DQ primitives
# ---------------------------------------------------------------------------

def test_scalar_qdq_bit_identical_to_legacy():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 48)) * 3.0
    q, scale = quantize_int8(x)
    lq, lscale, ldeq = _legacy_qdq(x)
    assert q.dtype == jnp.int8 and scale.shape == ()
    np.testing.assert_array_equal(np.asarray(q), np.asarray(lq))
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(lscale))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scale)),
                                  np.asarray(ldeq))


def test_compress_decompress_bit_identical_to_legacy():
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (32, 16)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (16,)),
             "none": None}
    out, err = compress_decompress(grads)
    for k in ("w", "b"):
        _, _, ldeq = _legacy_qdq(grads[k])
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ldeq))
        np.testing.assert_array_equal(
            np.asarray(err[k]),
            np.asarray(grads[k].astype(jnp.float32) - ldeq))
    assert out["none"] is None and err["none"] is None


@pytest.mark.parametrize("axis,scale_shape", [
    (-1, (6, 4)), ((0, 2), (4,)), (None, ())])
def test_axis_reduction_scale_shapes_and_roundtrip(axis, scale_shape):
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 4, 8))
    q, scale = quantize_int8(x, axis=axis)
    assert scale.shape == scale_shape
    deq = dequantize_int8(q, scale, axis=axis)
    # per-slice max-abs scale: elementwise error <= scale/2 of the slice
    err = jnp.abs(deq - x)
    s_b = scale if axis is None else jnp.expand_dims(scale, axis)
    assert bool(jnp.all(err <= s_b / 2 + 1e-6))


def test_keepdims_broadcasts_directly():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 8))
    q, scale = quantize_int8(x, axis=-1, keepdims=True)
    assert scale.shape == (8, 1)
    got = q.astype(jnp.float32) * scale
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(dequantize_int8(q, scale, axis=-1)))


def test_dequantize_dtype_override():
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 16)).astype(jnp.bfloat16)
    q, scale = quantize_int8(x, axis=-1)
    assert dequantize_int8(q, scale, axis=-1).dtype == jnp.float32
    assert dequantize_int8(q, scale, axis=-1,
                           dtype=jnp.bfloat16).dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# error feedback: single device
# ---------------------------------------------------------------------------

def test_error_feedback_recursion_exact():
    """e_{t+1} = (g + e_t) - DQ(Q(g + e_t)), exactly."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(6), (32, 32))}
    e = init_error_feedback(g)
    assert float(jnp.abs(e["w"]).max()) == 0.0
    out, e1 = compress_decompress(g, e)
    g32 = g["w"].astype(jnp.float32)
    want = g32 - dequantize_int8(*quantize_int8(g32))
    np.testing.assert_array_equal(np.asarray(e1["w"]), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(out["w"] + e1["w"]),
                                  np.asarray(g32))


def test_error_feedback_telescopes_to_true_gradient():
    """Constant gradient g over T steps: sum_t DQ_t = T*g + e_1 - e_{T+1},
    so the running mean converges at O(1/T) — the convergence contract of
    compressed training."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(7), (24, 24)) * 0.1}
    e = init_error_feedback(g)
    total = jnp.zeros_like(g["w"])
    T = 32
    for _ in range(T):
        out, e = compress_decompress(g, e)
        total = total + out["w"]
    # the telescoping identity holds to fp32 summation error
    np.testing.assert_allclose(
        np.asarray(total + e["w"]), np.asarray(T * g["w"]),
        rtol=0, atol=1e-4)
    # and the mean beats a single quantization step by a wide margin
    one_step_err = float(jnp.abs(
        dequantize_int8(*quantize_int8(g["w"])) - g["w"]).max())
    mean_err = float(jnp.abs(total / T - g["w"]).max())
    assert mean_err < one_step_err / 4


# ---------------------------------------------------------------------------
# error feedback: psum path (shard_map wire format)
# ---------------------------------------------------------------------------

def test_psum_path_matches_single_device_qdq():
    """With identical per-device gradients, the int8-psum + pmean-scale
    mean reduction must equal the single-device Q/DQ round trip exactly
    (q summed as int32 over D devices, divided back by D)."""
    n_dev = 4
    g = jax.random.normal(jax.random.PRNGKey(8), (16, 16))
    stacked = {"w": jnp.broadcast_to(g, (n_dev, *g.shape))}
    e0 = {"w": jnp.zeros((n_dev, *g.shape), jnp.float32)}

    def step(grads, err):
        return compress_decompress(grads, err, axis_name="dp")

    out, e1 = jax.vmap(step, axis_name="dp")(stacked, e0)
    ref_deq = dequantize_int8(*quantize_int8(g))
    for d in range(n_dev):
        np.testing.assert_array_equal(np.asarray(out["w"][d]),
                                      np.asarray(ref_deq))
        np.testing.assert_array_equal(
            np.asarray(e1["w"][d]),
            np.asarray(g.astype(jnp.float32) - ref_deq))


def test_psum_path_averages_heterogeneous_gradients():
    """Different per-device gradients: the wire format is int8 payloads
    psum'd as int32, scales pmean'd, divided back by D — pin that math
    exactly, and check error feedback tracks the *local* residual."""
    n_dev = 2
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    g0 = jax.random.normal(k1, (16, 16))
    g1 = jax.random.normal(k2, (16, 16))
    stacked = {"w": jnp.stack([g0, g1])}

    out, err = jax.vmap(
        lambda g: compress_decompress(g, None, axis_name="dp"),
        axis_name="dp")(stacked)
    # every device sees the same reduced gradient, and it is exactly the
    # dequantized int32 sum under the mean scale
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(out["w"][1]))
    q0, s0 = quantize_int8(g0)
    q1, s1 = quantize_int8(g1)
    want = (q0.astype(jnp.int32) + q1.astype(jnp.int32)).astype(jnp.float32) \
        * ((s0 + s1) / 2) / n_dev
    np.testing.assert_array_equal(np.asarray(out["w"][0]), np.asarray(want))
    for d, g in enumerate((g0, g1)):
        want = g - dequantize_int8(*quantize_int8(g))
        np.testing.assert_array_equal(np.asarray(err["w"][d]),
                                      np.asarray(want))
