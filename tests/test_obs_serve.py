"""Observability threaded through the serving engines.

The load-bearing guarantees, in order:

  * **bit-exactness** — attaching a Recorder never changes a single
    greedy token (the engine-vs-oracle parity suites stay the guard; here
    we pin recorder-on == recorder-off directly);
  * **counter audit** — the lifecycle counters the engine increments at
    scattered call sites (finished/expired/failed/preemptions/
    fault_kills/prefix_hits) exactly match counts re-derived from the
    request log + span log, across preemption, fault-soak, and
    prefix-sharing runs;
  * **span math** — a lone request's TTFT-in-steps equals the observed
    first-token step delta; preempted requests' spans grow the extra
    QUEUED/PREFILLING segments and still finish bit-exact;
  * **fenced timings** — with a recorder attached the prefill/decode
    sections are fenced (block_until_ready), so their sum dominates the
    drain wall-time on CPU where compute is the loop's cost.
"""
import numpy as np
import pytest

import jax

from repro.configs import apply_sparsity, get_config, reduce_config
from repro.models import LMModel
from repro.obs import (
    Recorder,
    audit_engine,
    derive_counts,
    validate_trace,
)
from repro.serve import (
    ContinuousEngine,
    FaultSchedule,
    run_sequential,
    restore_engine,
    save_engine,
)

# decode growth overflows a small pool (same shapes as the lifecycle
# suite): preemption tests reuse them against n_blocks=11
SHAPES = [(4, 8), (12, 10), (8, 9), (16, 6), (6, 10)]


@pytest.fixture(scope="module")
def lm():
    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5,
                         backend="xla_masked", min_dim=64)
    model = LMModel(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def make_workload(model, shapes=SHAPES, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"rid": i, "prompt": rng.integers(
            0, model.cfg.vocab_size, s).astype(np.int32),
         "max_new_tokens": g}
        for i, (s, g) in enumerate(shapes)
    ]


def run_engine(model, params, workload, recorder=None, **kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_request_len", 40)
    eng = ContinuousEngine(model, params, recorder=recorder, **kw)
    for r in workload:
        eng.submit(r["prompt"], r["max_new_tokens"])
    out = eng.drain()
    return eng, out


# -- bit-exactness ------------------------------------------------------------------


def test_recorder_does_not_change_tokens(lm):
    model, params = lm
    wl = make_workload(model, seed=3)
    _, base = run_engine(model, params, wl)
    _, obs = run_engine(model, params, wl, recorder=Recorder())
    assert set(base) == set(obs)
    for rid in base:
        np.testing.assert_array_equal(base[rid], obs[rid])


# -- the full stack on one mixed run ------------------------------------------------


def test_recorder_mixed_workload_full_stack(lm, tmp_path):
    import time

    model, params = lm
    wl = make_workload(model, seed=1)
    rec = Recorder()
    eng = ContinuousEngine(model, params, page_size=4, max_slots=3,
                           max_request_len=40, prefill_chunk=6,
                           recorder=rec)
    for r in wl:
        eng.submit(r["prompt"], r["max_new_tokens"])
    t0 = time.perf_counter()
    out = eng.drain()
    wall = time.perf_counter() - t0
    assert len(out) == len(wl)

    # spans: every request finished with tokens; percentiles well-formed
    agg = rec.spans.aggregate()
    assert agg["requests"] == len(wl) and agg["with_tokens"] == len(wl)
    assert agg["tokens"] == sum(g for _, g in SHAPES)
    for table in (agg["ttft_s"], agg["ttft_steps"], agg["tpot_s"]):
        assert set(table) == {"p50", "p90", "p99"}
        assert table["p50"] <= table["p90"] <= table["p99"]

    # counter audit against the request log + token stamps
    audit = audit_engine(eng, spans=rec.spans)
    assert audit["ok"], audit["mismatches"]
    assert audit["derived"]["finished"] == len(wl)

    # trace: validates, has the expected tracks, renders to disk
    doc = rec.trace.to_json()
    stats = validate_trace(doc)
    assert stats["slices"] > 0
    slice_names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"step", "decode"} <= slice_names
    assert "prefill_chunk" in slice_names    # prefill_chunk=6 was active
    path = tmp_path / "trace.json"
    rec.trace.save(str(path))
    from repro.obs import validate_trace_file

    validate_trace_file(str(path))

    # registry: stats mirrored + timed histograms populated + prom renders
    snap = rec.registry.snapshot()
    assert snap["serve_finished"] == len(wl)
    assert snap["serve_generated_tokens"] == agg["tokens"]
    assert snap["decode_seconds"]["count"] == eng.stats["decode_steps"]
    assert snap["sched_running"] >= 0       # occupancy gauges exported
    text = rec.registry.render_prometheus()
    assert "serve_finished" in text and "decode_seconds_bucket" in text

    # fenced timings: on CPU the model compute is the cost of the loop,
    # so the fenced prefill+decode sections must dominate the drain wall
    timed = eng.stats["prefill_time_s"] + eng.stats["decode_time_s"]
    assert timed > 0.3 * wall, (timed, wall)


# -- span math ----------------------------------------------------------------------


def test_single_request_ttft_equals_first_token_step_delta(lm):
    model, params = lm
    rec = Recorder()
    eng = ContinuousEngine(model, params, page_size=4, max_slots=2,
                           max_request_len=24, recorder=rec)
    rng = np.random.default_rng(5)
    rid = eng.submit(rng.integers(0, model.cfg.vocab_size, 9).astype(
        np.int32), 4)
    first_token_step = None
    steps = 0
    while not eng.idle:
        eng.step()
        steps += 1
        if first_token_step is None and eng.requests[rid].generated:
            first_token_step = steps - 1   # token landed in step index
    m = rec.spans.request_metrics(rid)
    assert m["n_tokens"] == 4
    assert m["ttft_steps"] == first_token_step
    assert m["preemptions"] == 0 and m["lost_steps"] == 0
    # lone request: fleet aggregate collapses onto the request itself
    agg = rec.spans.aggregate()
    assert agg["ttft_steps"]["p50"] == m["ttft_steps"]
    assert agg["ttft_steps"]["p99"] == m["ttft_steps"]


def test_preempted_spans_resume_and_stay_bit_exact(lm):
    model, params = lm
    wl = make_workload(model)
    rec = Recorder()
    eng, out = run_engine(model, params, wl, recorder=rec,
                          reserve="prompt", n_blocks=11)
    assert eng.stats["preemptions"] >= 2, eng.stats
    ref = run_sequential(model, params, wl, cache_len=eng.gather_tokens)
    for r in wl:
        np.testing.assert_array_equal(out[r["rid"]], ref[r["rid"]])
    # the span of every preempted request shows the extra QUEUED segment
    # (and matching lost recompute steps), and agrees with the engine's
    # per-request counter
    n_preempted = 0
    for rid, req in eng.requests.items():
        m = rec.spans.request_metrics(rid)
        assert m["preemptions"] == req.preemptions, (rid, m)
        if req.preemptions:
            n_preempted += 1
            span = rec.spans.spans[rid]
            queued = [s for s in span.segments if s.state == "QUEUED"]
            assert len(queued) == 1 + req.preemptions
            if m["n_tokens"] and m["lost_steps"] == 0:
                # preempted before its first token: nothing lost yet
                assert span.token_steps[0] >= queued[-1].end_step
    assert n_preempted >= 1
    agg = rec.spans.aggregate()
    assert agg["preemptions"] == eng.stats["preemptions"]
    audit = audit_engine(eng, spans=rec.spans)
    assert audit["ok"], audit["mismatches"]


# -- counter audits across the adversarial runs -------------------------------------


def test_counter_audit_fault_soak(lm):
    model, params = lm
    wl = make_workload(model, seed=2)
    hit = 0
    for seed in range(3):
        faults = FaultSchedule.random(seed, horizon=24, n_events=4,
                                      max_drop=3)
        rec = Recorder()
        eng, out = run_engine(model, params, wl, recorder=rec,
                              reserve="prompt", n_blocks=13, faults=faults,
                              preempt_backoff=0)
        audit = audit_engine(eng, spans=rec.spans)
        assert audit["ok"], (seed, audit["mismatches"])
        hit += eng.stats["fault_kills"] + eng.stats["preemptions"]
        # faults landed as instants on the trace
        validate_trace(rec.trace.to_json())
    assert hit > 0, "no fault ever fired across the soak seeds"


def test_counter_audit_prefix_sharing(lm):
    model, params = lm
    rng = np.random.default_rng(0)
    base = rng.integers(1, model.cfg.vocab_size, 16).astype(np.int32)
    cold = rng.integers(1, model.cfg.vocab_size, 10).astype(np.int32)
    wl = [
        {"rid": 0, "prompt": base.copy(), "max_new_tokens": 4},
        {"rid": 1, "prompt": base.copy(), "max_new_tokens": 4},
        {"rid": 2, "prompt": base[:8].copy(), "max_new_tokens": 4},
        {"rid": 3, "prompt": cold, "max_new_tokens": 4},
    ]
    rec = Recorder()
    eng, out = run_engine(model, params, wl, recorder=rec, max_slots=1,
                          max_request_len=32, prefix_cache=True)
    assert eng.stats["prefix_hits"] > 0
    audit = audit_engine(eng, spans=rec.spans)
    assert audit["ok"], audit["mismatches"]
    # spans carry the per-request discount the stats only hold in sum
    assert audit["derived"]["prefix_hit_tokens"] == \
        eng.stats["prefix_hit_tokens"]
    per_req = [rec.spans.request_metrics(r["rid"]).get(
        "prefix_hit_tokens", 0) for r in wl]
    assert sum(per_req) == eng.stats["prefix_hit_tokens"]
    assert per_req[1] > 0                  # the exact repeat hit
    assert per_req[3] == 0                 # the cold miss did not


def test_derive_counts_without_spans(lm):
    model, params = lm
    wl = make_workload(model, seed=4, shapes=[(4, 3), (8, 2)])
    eng, _ = run_engine(model, params, wl)
    d = derive_counts(eng)
    assert d["finished"] == 2 and d["preemptions"] == 0
    audit = audit_engine(eng)               # span-less audit still works
    assert audit["ok"], audit["mismatches"]


# -- snapshots keep working with EngineStats ----------------------------------------


def test_snapshot_roundtrip_with_engine_stats(lm, tmp_path):
    model, params = lm
    wl = make_workload(model, seed=6, shapes=[(6, 5), (10, 4), (4, 6)])
    rec = Recorder()
    eng = ContinuousEngine(model, params, page_size=4, max_slots=2,
                           max_request_len=24, recorder=rec)
    for r in wl:
        eng.submit(r["prompt"], r["max_new_tokens"])
    for _ in range(3):
        eng.step()
    path = str(tmp_path / "snap.npz")
    meta = save_engine(eng, path)
    assert meta["stats"]["prompt_tokens"] == eng.stats["prompt_tokens"]
    # snapshot instants are on the original engine's trace
    assert any(e.get("name") == "snapshot"
               for e in rec.trace.to_json()["traceEvents"])

    # restore with a fresh recorder: stats resync into the new registry
    rec2 = Recorder()
    eng2 = restore_engine(path, model, params, recorder=rec2)
    assert dict(eng2.stats) == dict(eng.stats)
    assert rec2.registry.snapshot()["serve_prompt_tokens"] == \
        eng.stats["prompt_tokens"]
    out2 = eng2.drain()
    ref = run_sequential(model, params, wl, cache_len=eng2.gather_tokens)
    for r in wl:
        np.testing.assert_array_equal(out2[r["rid"]], ref[r["rid"]])
    audit = audit_engine(eng2, spans=None)   # spans2 missed pre-crash tokens
    assert audit["ok"], audit["mismatches"]
