"""End-to-end driver: train an RBGP4-sparse LM with the full substrate.

Wraps launch/train.py (checkpointing, auto-resume, failure drills, grad
accumulation) with a self-contained "paper technique on an LM" setup:
a TinyLlama-family decoder with every projection RBGP4-sparse at 75%.

Defaults are sized for this single-core CPU container (~2M params,
200 steps, loss drops from ~7 to <3 on the synthetic recurrence data).
On a TPU pod slice the same command takes --size paper (~110M params,
the assignment's "train ~100M model for a few hundred steps").

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 200]
Drill: add --simulate-failure 50, rerun to watch auto-resume.
"""
import argparse
import sys

from repro.configs import TrainConfig, get_config, reduce_config, apply_sparsity
from repro.data import Prefetcher, TokenStream
from repro.models import LMModel
from repro.train import Trainer


def config(size: str):
    base = get_config("tinyllama-1.1b")
    if size == "cpu":
        cfg = reduce_config(base).with_(n_layers=4, vocab_size=512)
    elif size == "paper":  # ~110M — for real accelerators
        cfg = base.with_(
            name="tinyllama-110m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        )
    else:
        raise ValueError(size)
    return apply_sparsity(cfg, pattern="rbgp4", sparsity=0.75,
                          backend="xla_masked", min_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="cpu", choices=["cpu", "paper"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    cfg = config(args.size)
    model = LMModel(cfg)
    print(f"model: {cfg.name} ({model.n_params():,} params, "
          f"rbgp4 @ {cfg.sparsity.sparsity:.0%} on all projections)")

    def loss_fn(params, batch):
        loss, (ce, aux) = model.loss(params, batch, train=True)
        return loss, {"ce": ce}

    tcfg = TrainConfig(optimizer="sgdm", lr=args.lr, schedule="cosine",
                       total_steps=args.steps, warmup_steps=args.steps // 10,
                       checkpoint_every=50, checkpoint_dir=args.checkpoint_dir)
    data = Prefetcher(TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0))
    params = model.init(__import__("jax").random.PRNGKey(0))
    tr = Trainer(loss_fn, params, tcfg, data)
    resumed = tr.try_resume()
    if resumed:
        print(f"auto-resumed from step {resumed}")
    tr.hooks.append(lambda s, m: s % 20 == 0 and print(
        f"step {s:5d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
        f"({m['step_time_s']*1e3:.0f} ms)", flush=True))
    try:
        tr.run(args.steps - int(tr.state.step),
               fail_at_step=args.simulate_failure)
    except RuntimeError as e:
        if "simulated node failure" in str(e):
            print(f"FAILURE DRILL: {e} — rerun this command to auto-resume")
            sys.exit(42)
        raise
    losses = [h["loss"] for h in tr.history]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
