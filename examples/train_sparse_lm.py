"""End-to-end driver: train an RBGP4-sparse LM with the full substrate.

Wraps launch/train.py (checkpointing, auto-resume, failure drills, grad
accumulation) with a self-contained "paper technique on an LM" setup:
a TinyLlama-family decoder sparsified through the SparsityPlan API.

Two profiles (--profile):
  * ``uniform``: every projection RBGP4-sparse at 75% — the classic
    single-knob setup, written as a one-rule plan;
  * ``depth`` (default): a depth-profiled heterogeneous plan — early
    layers (closest to the embedding, where the paper keeps the first
    layer dense) at 50% with dense attention outputs, deep layers at 75%
    with attention outputs one pow-2 step denser (50%).  Layers whose
    resolved specs differ
    can't stack under one lax.scan period, so the Stack automatically
    falls back to explicit layers for this plan.

The plan's fingerprint is stamped into every checkpoint; restoring under
a different profile refuses loudly instead of scrambling masks.

Defaults are sized for this single-core CPU container (~2M params,
200 steps, loss drops from ~7 to <3 on the synthetic recurrence data).
On a TPU pod slice the same command takes --size paper (~110M params,
the assignment's "train ~100M model for a few hundred steps").

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 200]
Drill: add --simulate-failure 50, rerun to watch auto-resume.
"""
import argparse
import sys

from repro.configs import TrainConfig, get_config, reduce_config, apply_sparsity
from repro.data import Prefetcher, TokenStream
from repro.models import LMModel
from repro.sparsity import PatternSpec, PlanRule, SparsityPlan
from repro.train import Trainer


def make_plan(profile: str, n_layers: int) -> SparsityPlan:
    def spec(sp):
        return PatternSpec(pattern="rbgp4", sparsity=sp,
                           backend="xla_masked", min_dim=64)

    if profile == "uniform":
        return SparsityPlan.uniform(spec(0.75), note="uniform 75%")
    # depth profile: shallow half at 50% with dense attention output
    # projections, deep half at 75% with wo one pow-2 step denser (50%)
    shallow = "|".join(f"l{i}" for i in range(n_layers // 2))
    deep = "|".join(f"l{i}" for i in range(n_layers // 2, n_layers))
    return SparsityPlan(rules=(
        PlanRule(rf"({shallow})\.attn\.wo", PatternSpec(),
                 note="shallow wo: kept dense"),
        PlanRule(rf"({shallow})\..*", spec(0.5), note="shallow half @ 50%"),
        PlanRule(rf"({deep})\.attn\.wo", spec(0.5),
                 note="deep wo: one step denser"),
        PlanRule(rf"({deep})\..*", spec(0.75), note="deep half @ 75%"),
    ))


def config(size: str, profile: str):
    base = get_config("tinyllama-1.1b")
    if size == "cpu":
        cfg = reduce_config(base).with_(n_layers=4, vocab_size=512)
    elif size == "paper":  # ~110M — for real accelerators
        cfg = base.with_(
            name="tinyllama-110m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        )
    else:
        raise ValueError(size)
    return apply_sparsity(cfg, plan=make_plan(profile, cfg.n_layers))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="cpu", choices=["cpu", "paper"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--profile", default="depth",
                    choices=["uniform", "depth"],
                    help="sparsity plan: one-rule uniform 75%%, or the "
                         "depth-profiled heterogeneous plan")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    cfg = config(args.size, args.profile)
    model = LMModel(cfg)
    plan = cfg.sparsity_rules
    print(f"model: {cfg.name} ({model.n_params():,} params, "
          f"{args.profile} plan {plan.fingerprint()}, "
          f"{len(plan.rules)} rules)")
    for r in plan.rules:
        print(f"  {r.spec.pattern}@{r.spec.sparsity:.2f}  {r.note}")

    def loss_fn(params, batch):
        loss, (ce, aux) = model.loss(params, batch, train=True)
        return loss, {"ce": ce}

    tcfg = TrainConfig(optimizer="sgdm", lr=args.lr, schedule="cosine",
                       total_steps=args.steps, warmup_steps=args.steps // 10,
                       checkpoint_every=50, checkpoint_dir=args.checkpoint_dir)
    data = Prefetcher(TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0))
    params = model.init(__import__("jax").random.PRNGKey(0))
    # the plan fingerprint rides with every checkpoint: resuming this
    # directory under the other --profile refuses instead of mixing masks
    tr = Trainer(loss_fn, params, tcfg, data,
                 plan_fingerprint=plan.fingerprint())
    resumed = tr.try_resume()
    if resumed:
        print(f"auto-resumed from step {resumed}")
    tr.hooks.append(lambda s, m: s % 20 == 0 and print(
        f"step {s:5d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
        f"({m['step_time_s']*1e3:.0f} ms)", flush=True))
    try:
        tr.run(args.steps - int(tr.state.step),
               fail_at_step=args.simulate_failure)
    except RuntimeError as e:
        if "simulated node failure" in str(e):
            print(f"FAILURE DRILL: {e} — rerun this command to auto-resume")
            sys.exit(42)
        raise
    losses = [h["loss"] for h in tr.history]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
