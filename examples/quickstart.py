"""Quickstart: the RBGP4 pattern end to end in two minutes.

  1. design a TPU-tuned RBGP4 factorization for a 1024x1024 layer @ 75%,
  2. verify the theory: factors are Ramanujan, the product's spectral gap
     approaches the ideal (paper Theorem 1), connectivity storage is
     succinct (paper Fig. 3),
  3. run the Pallas RBGP4MM kernel (interpret mode on CPU) against the
     pure-jnp oracle,
  4. dispatch one layer through every registered sparse backend via the
     pluggable API (repro.sparsity.api) and check they agree,
  5. train a tiny RBGP4-sparse MLP on a toy task — the mask is fixed,
     learning happens through the sparse connections only,
  6. the SparsityPlan API: lower a uniform SparsityConfig to a plan
     (bit-identical masks), solve a global memory budget into per-layer
     pow-2 sparsities, certify the factors spectrally, and round-trip the
     plan through JSON.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RBGP4Layout,
    design_rbgp4,
    ideal_spectral_gap,
    is_ramanujan,
    second_singular_value,
)
from repro.kernels import RBGP4Op
from repro.kernels import ref as kref
from repro.sparsity import (
    SparseLinear,
    SparsityConfig,
    available_backends,
    dense_weight,
    sparse_linear,
)

# 1. ------------------------------------------------------------------
spec = design_rbgp4(4096, 4096, 0.9375)
layout = RBGP4Layout(spec)
print("RBGP4 factorization of a 4096x4096 layer @ 93.75% sparsity:")
print(f"  G_o {spec.g_o} sp={spec.sp_o}   (tile-level sparsity: skip whole "
      f"{spec.tile_m}x{spec.tile_k} tiles)")
print(f"  G_i {spec.g_i} sp={spec.sp_i}   (intra-tile sparsity)")
print(f"  G_r*G_b -> dense ({spec.group_rows}, {spec.chunk_cols}) blocks "
      f"(MXU sublane x lane packing)")

# 2. ------------------------------------------------------------------
print("\nTheory checks:")
for name, g in (("G_o", layout.graph_o), ("G_i", layout.graph_i)):
    lam2 = second_singular_value(g)
    print(f"  {name}: {g.n_left}x{g.n_right} d_l={g.d_left} "
          f"lambda2={lam2:.3f} Ramanujan={is_ramanujan(g)}")
ps = layout.product_structure()
s = ps.storage_summary()
print(f"  product: {s['edges']:,} edges, index stored as "
      f"{s['stored_index_edges']} base-graph edges "
      f"({s['index_compression']:.0f}x succinct — paper Fig. 3 property)")
mem = layout.memory_bytes()
print(f"  memory: values {mem['values']/1e3:.0f} KB + index "
      f"{mem['index_succinct']/1e3:.1f} KB "
      f"(unstructured would need {mem['index_full']/1e3:.0f} KB of index)")

# 3. ------------------------------------------------------------------
print("\nPallas RBGP4MM kernel vs oracle (interpret mode):")
op = RBGP4Op(layout, interpret=True)
key = jax.random.PRNGKey(0)
w = op.init_data(key)
x = jax.random.normal(jax.random.PRNGKey(1), (spec.k, 64))
out = op.matmul(w, x)
want = kref.ref_rbgp4mm(layout, w, x)
err = float(jnp.abs(out - want).max())
print(f"  O = W_s @ I: out {out.shape}, max |kernel - oracle| = {err:.2e}")
assert err < 1e-4

# 4. ------------------------------------------------------------------
print("\nOne layer through every registered backend (pluggable API):")
lin = SparseLinear(512, 512, SparsityConfig(pattern="rbgp4", sparsity=0.75,
                                            backend="auto", min_dim=1))
weight = lin.init(jax.random.PRNGKey(5))   # CompactWeight pytree
xq = jax.random.normal(jax.random.PRNGKey(6), (8, 512))
y_ref = xq @ dense_weight(weight).T
for name in available_backends(weight=weight):
    y = sparse_linear(weight, xq, backend=name)
    err = float(jnp.abs(y - y_ref).max())
    print(f"  backend={name:12s} max err vs dense ref = {err:.2e}")
    assert err < 1e-3
print(f"  (auto on this host resolves to "
      f"{'pallas' if jax.default_backend() == 'tpu' else 'xla_compact'})")

# 5. ------------------------------------------------------------------
print("\nTraining through the fixed RBGP4 mask (tiny regression):")
lin = SparseLinear(256, 256, SparsityConfig(pattern="rbgp4", sparsity=0.75,
                                            backend="xla_masked", min_dim=1))
params = lin.init(jax.random.PRNGKey(2))
# target is itself RBGP4-sparse (same mask, different values): the sparse
# student can represent it exactly, so MSE should collapse
w_true = lin.dense_weight(lin.init(jax.random.PRNGKey(3))) / 4.0
xs = jax.random.normal(jax.random.PRNGKey(4), (512, 256))
ys = xs @ w_true.T

from repro.utils import merge_trees, split_trainable

train, static = split_trainable(params)

@jax.jit
def step(train, lr=0.5):
    def loss(t):
        pred = lin.apply(merge_trees(t, static), xs)
        return jnp.mean((pred - ys) ** 2)
    l, g = jax.value_and_grad(loss)(train)
    return jax.tree_util.tree_map(
        lambda p, gg: None if p is None else p - lr * gg, train, g,
        is_leaf=lambda v: v is None), l

losses = []
for i in range(500):
    train, l = step(train)
    losses.append(float(l))
print(f"  mse step 0: {losses[0]:.4f} -> step 500: {losses[-1]:.4f} "
      f"({losses[0]/losses[-1]:.0f}x down; mask stayed fixed)")
assert losses[-1] < losses[0] / 5

# 6. ------------------------------------------------------------------
print("\nSparsityPlan: per-layer sparsity as declarative path rules")
from repro.sparsity import (
    SparsityPlan, certify, lower_config, plan_density, solve_budget,
)

# a SparsityConfig is just the one-rule uniform plan (the legacy shim):
uni_cfg = SparsityConfig(pattern="rbgp4", sparsity=0.75, min_dim=1)
uniform = lower_config(uni_cfg)
lin_a = SparseLinear(512, 512, uni_cfg, name="layer")     # config by value
lin_b = SparseLinear(512, 512, uniform, name="layer")     # plan by path
assert (lin_a.pattern.mask() == lin_b.pattern.mask()).all()
print(f"  uniform plan {uniform.fingerprint()}: masks bit-identical to the "
      f"SparsityConfig path")

# budget solving: give the solver the model's (path -> shape) table and a
# global memory target; it allocates pow-2 steps largest-matmul-first
shapes = {
    "l0.attn.wq": (1024, 1024), "l0.mlp.gate": (4096, 1024),
    "l0.mlp.down": (1024, 4096), "l0.attn.wk": (128, 1024),
}
plan = solve_budget(shapes, target_density=0.25, min_dim=256)
print(f"  budget 0.25 -> achieved {plan_density(plan, shapes):.4f}:")
for r in plan.rules:
    print(f"    {r.spec.pattern}@{r.spec.sparsity:.4f}  <- {r.match[:60]}")

# spectral certification + JSON round trip (bit-identical masks)
rep = certify(plan, shapes)
print(f"  certify: {rep['summary']['n_proper_ramanujan']} proper Ramanujan "
      f"factors, all within bound: {rep['summary']['all_ok']}")
restored = SparsityPlan.loads(plan.dumps())
assert restored.fingerprint() == plan.fingerprint()
for path, (m, k) in shapes.items():
    assert (restored.pattern_for(path, m, k).mask()
            == plan.pattern_for(path, m, k).mask()).all()
print("  JSON round trip: fingerprint + masks bit-identical")
assert rep["summary"]["all_ok"]

print("\nquickstart OK")
