"""Serving example: batched prefill + decode with an RBGP4-sparse LM.

Demonstrates the serving substrate the dry-run lowers at production shapes:
KV caches (full and sliding-window), greedy/temperature sampling, and the
compact-storage sparse projections.  Uses the gemma3-family reduced config
so both cache kinds (5 local : 1 global) are exercised.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_sparsity, get_config, reduce_config
from repro.data import TokenStream
from repro.models import LMModel

BATCH, PROMPT, GEN = 4, 24, 24

cfg = reduce_config(get_config("gemma3-4b")).with_(n_layers=6)
cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5,
                     backend="xla_masked", min_dim=64)
model = LMModel(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"serving {cfg.name}: {model.n_params():,} params, layer pattern "
      f"{cfg.layer_pattern} (window={cfg.sliding_window})")

prompts = jnp.asarray(
    TokenStream(cfg.vocab_size, BATCH, PROMPT, seed=1).batch_at(0))
cache = model.init_cache(BATCH, PROMPT + GEN, jnp.float32)

prefill = jax.jit(model.prefill)
decode = jax.jit(model.decode_step, donate_argnums=(2,))

t0 = time.perf_counter()
logits, cache = prefill(params, {"tokens": prompts}, cache)
logits.block_until_ready()
print(f"prefill {BATCH}x{PROMPT}: {(time.perf_counter()-t0)*1e3:.0f} ms")

tok = jnp.argmax(logits, -1)
outs = []
t0 = time.perf_counter()
for i in range(GEN):
    outs.append(np.asarray(tok))
    logits, cache = decode(params, tok[:, None], cache, jnp.int32(PROMPT + i))
    tok = jnp.argmax(logits, -1)
jax.block_until_ready(tok)
dt = time.perf_counter() - t0
print(f"decode {GEN} steps: {dt*1e3:.0f} ms "
      f"({BATCH*GEN/dt:.0f} tok/s, {dt/GEN*1e3:.1f} ms/step)")
print(f"continuation (req 0): {np.stack(outs, 1)[0].tolist()}")

# consistency: greedy decode must match teacher-forced forward
full = jnp.concatenate([prompts, jnp.stack([jnp.asarray(o) for o in outs], 1)], 1)
ref_logits, _ = model.forward(params, {"tokens": full})
ref_next = jnp.argmax(ref_logits[:, PROMPT - 1:-1], -1)
match = float(jnp.mean(ref_next == jnp.stack([jnp.asarray(o) for o in outs], 1)))
print(f"teacher-forced agreement: {match:.2%}")
assert match > 0.99, "incremental decode diverged from full forward"
print("serve example OK")
