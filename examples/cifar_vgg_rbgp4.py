"""The paper's experiment, end to end: VGG-family CIFAR classification with
predefined sparsity + knowledge distillation (paper §6 protocol).

CIFAR is not available offline, so the default runs on deterministic
synthetic class-prototype images (DESIGN.md §7); point --data-npz at a real
CIFAR archive (images float32 NHWC in [0,1], labels int) to run the paper's
exact setting.  Protocol reproduced: dense teacher trained first, sparse
students (unstructured / rbgp4 at --sparsity) trained with KD from the
teacher, SGD momentum 0.9, weight decay 1e-4, step LR schedule.

Run: PYTHONPATH=src python examples/cifar_vgg_rbgp4.py --steps 80
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.data import GaussianClassImages
from repro.models.vision import VisionConfig, WideResNet
from repro.sparsity import SparsityConfig
from repro.train import Trainer, distillation_loss


def make_model(pattern, sparsity):
    sp = (SparsityConfig() if pattern == "dense" else
          SparsityConfig(pattern=pattern, sparsity=sparsity, min_dim=32))
    # WRN-10-1 stands in for the paper's nets at CPU scale; --full uses 40-4
    return WideResNet(VisionConfig(name=f"wrn-{pattern}", sparsity=sp,
                                   depth=10, width=1))


def ce_loss(logits, labels):
    ll = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))


def train_one(model, steps, data_seed, teacher=None, alpha=0.0, lr=0.05):
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(p, batch):
        logits = model.apply(p, batch["images"], train=True)
        hard = ce_loss(logits, batch["labels"])
        if teacher is not None and alpha > 0:
            t_model, t_params = teacher
            t_logits = t_model.apply(t_params, batch["images"], train=True)
            loss = distillation_loss(logits, t_logits, hard, alpha)
        else:
            loss = hard
        acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
        return loss, {"acc": acc}

    tcfg = TrainConfig(optimizer="sgdm", lr=lr, momentum=0.9,
                       weight_decay=1e-4, schedule="step",
                       lr_step_epochs=(steps // 2, 3 * steps // 4),
                       lr_step_gamma=0.2)
    tr = Trainer(loss_fn, params, tcfg,
                 GaussianClassImages(10, 64, seed=data_seed),
                 checkpoint=False)
    hist = tr.run(steps)
    return tr.state.full_params(), hist


def evaluate(model, params, batch):
    logits = model.apply(params, jnp.asarray(batch["images"]), train=True)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(batch["labels"])))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--sparsity", type=float, default=0.75)
    ap.add_argument("--kd-alpha", type=float, default=0.5)
    ap.add_argument("--data-npz", default=None,
                    help="optional real CIFAR npz {images, labels}")
    args = ap.parse_args()

    # held-out: same class prototypes (same seed), unseen noise draws
    test = GaussianClassImages(10, 512, seed=3).batch_at(10_000)
    if args.data_npz:
        import numpy as np

        d = np.load(args.data_npz)
        test = {"images": d["images"][-512:], "labels": d["labels"][-512:]}
        print("using real data from", args.data_npz)

    print(f"1) dense teacher ({args.steps} steps, SGD-m 0.9, wd 1e-4, "
          f"step schedule — paper recipe)")
    teacher_model = make_model("dense", 0.0)
    teacher_params, hist = train_one(teacher_model, args.steps, data_seed=3)
    acc_d = evaluate(teacher_model, teacher_params, test)
    print(f"   dense: test acc {acc_d:.3f}")

    results = {"dense": acc_d}
    for pattern in ("unstructured", "rbgp4"):
        print(f"2) {pattern} student @ {args.sparsity:.0%} with KD "
              f"(alpha={args.kd_alpha})")
        model = make_model(pattern, args.sparsity)
        params, hist = train_one(
            model, args.steps, data_seed=3,
            teacher=(teacher_model, teacher_params), alpha=args.kd_alpha)
        acc = evaluate(model, params, test)
        results[pattern] = acc
        print(f"   {pattern}: test acc {acc:.3f}")

    print("\nsummary (paper claim: rbgp4 ~ unstructured accuracy at equal "
          "sparsity, with structured-runtime wins):")
    for k, v in results.items():
        print(f"  {k:>13}: {v:.3f}")
    gap = abs(results["rbgp4"] - results["unstructured"])
    print(f"  |rbgp4 - unstructured| = {gap:.3f}")


if __name__ == "__main__":
    main()
