"""Analytic TPU-v5e roofline model of the RBGP4MM kernel.

The model now lives in :mod:`repro.kernels.perf_model` so the in-tree
autotuner (:mod:`repro.kernels.autotune`) can score candidate launch
configurations with it; this module re-exports it unchanged for the
benchmark harness (``kernel_hillclimb``, ``table2``/``table3``,
``stacked_experts``).
"""
from __future__ import annotations

from repro.kernels.perf_model import (  # noqa: F401
    HBM_BW,
    PEAK_FLOPS,
    KernelEstimate,
    estimate_dense,
    estimate_rbgp4mm,
    estimate_rbgp4mm_dims,
    estimate_unstructured,
)

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "KernelEstimate",
    "estimate_rbgp4mm",
    "estimate_rbgp4mm_dims",
    "estimate_dense",
    "estimate_unstructured",
]
