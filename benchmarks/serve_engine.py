"""Serving-engine benchmark: static batching vs continuous batching.

The paper's runtime claim, end-to-end: sparsity (and cache compaction) only
matter if *serving* gets faster, and decode is the memory-bound regime.
This benchmark drives both engines over the same mixed-length request
workload (reduced tinyllama on CPU — the same code path pjit-shards on TPU)
and reports:

  * tokens/sec for each engine (prefill + decode wall clock, steady-state:
    a full warmup pass first so jit compilation is excluded);
  * the wasted lockstep row-steps the static engine burns on finished rows;
  * paged-cache occupancy (allocated blocks / pool) for the continuous
    engine vs the ``batch x max_len`` slots the static engine reserves.

Both engines run greedy sampling, so their outputs must agree token-for-
token with each other (asserted here) and with the sequential reference
(locked down in tests/test_serve_engine.py).

CSV rows: name,us_per_call(=us per generated token),derived.
Standalone:
  PYTHONPATH=src python -m benchmarks.serve_engine --json SERVE.json \
      --min-speedup 1.5
"""
from __future__ import annotations

import argparse
import json
import time

N_REQUESTS = 16
PROMPT_LENS = (8, 16, 24, 32)
GEN_LENS = (4, 8, 16, 32)
PAGE = 8
SLOTS = 8
STATIC_BATCH = 4
SEED = 0


def _workload(cfg, n_requests, seed):
    from repro.data import RequestStream

    return RequestStream(cfg.vocab_size, n_requests,
                         prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS,
                         seed=seed).requests()


def _run_engine(kind, model, params, workload):
    from repro.serve import make_engine

    max_len = max(r["prompt"].shape[0] + r["max_new_tokens"]
                  for r in workload)
    if kind == "continuous":
        eng = make_engine("continuous", model, params, page_size=PAGE,
                          max_slots=SLOTS, max_request_len=max_len)
    else:
        eng = make_engine("static", model, params, batch=STATIC_BATCH)
    for r in workload:
        eng.submit(r["prompt"], r["max_new_tokens"])
    t0 = time.perf_counter()
    out = eng.drain()
    return eng, out, time.perf_counter() - t0


def run(print_fn=print, n_requests: int = N_REQUESTS,
        seed: int = SEED) -> list[tuple]:
    import jax

    from repro.configs import apply_sparsity, get_config, reduce_config
    from repro.models import LMModel

    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5, backend="auto",
                         min_dim=64)
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    workload = _workload(cfg, n_requests, seed)
    n_gen = sum(r["max_new_tokens"] for r in workload)
    print_fn(f"# workload: {len(workload)} requests, prompts "
             f"{PROMPT_LENS}, gens {GEN_LENS}, {n_gen} new tokens total")

    results = {}
    for kind in ("static", "continuous"):
        _run_engine(kind, model, params, workload)       # warmup: compile
        eng, out, wall = _run_engine(kind, model, params, workload)
        done = {rid: toks for rid, toks in out.items()
                if len(toks) == workload[rid]["max_new_tokens"]}
        assert len(done) == len(workload), (
            f"{kind}: only {len(done)}/{len(workload)} requests completed"
        )
        results[kind] = (eng, out, wall)
        print_fn(f"# {kind:10s}: {n_gen} tokens in {wall*1e3:7.0f} ms "
                 f"-> {n_gen/wall:7.0f} tok/s "
                 f"({int(eng.stats['decode_steps'])} decode steps, "
                 f"{int(eng.stats['wasted_row_steps'])} wasted row-steps)")

    cont_eng, cont_out, cont_wall = results["continuous"]
    stat_eng, stat_out, stat_wall = results["static"]
    for rid in cont_out:
        assert (cont_out[rid] == stat_out[rid]).all(), (
            f"greedy outputs diverge between engines for request {rid}"
        )
    print_fn("# greedy outputs identical across engines for all requests")

    speedup = stat_wall / cont_wall
    occ = (cont_eng.stats["allocated_block_steps"]
           / max(cont_eng.stats["block_steps"], 1))
    # static engine's reservation efficiency: live tokens / (B x max_len)
    static_occ = (stat_eng.stats["live_token_steps"]
                  / max(stat_eng.stats["cache_slot_steps"], 1))
    print_fn(f"# continuous/static speedup: {speedup:.2f}x; cache "
             f"occupancy: paged {occ:.1%} of pool vs static "
             f"{static_occ:.1%} of batch x max_len slots")
    return [
        ("serve/static_tok", stat_wall / n_gen * 1e6, n_gen / stat_wall),
        ("serve/continuous_tok", cont_wall / n_gen * 1e6, n_gen / cont_wall),
        ("serve/speedup", 0.0, speedup),
        ("serve/paged_occupancy", 0.0, occ),
        ("serve/static_occupancy", 0.0, static_occ),
        ("serve/wasted_row_steps", 0.0,
         stat_eng.stats["wasted_row_steps"]),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="",
                    help="write rows as a name -> us_per_call/derived map")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless continuous >= this x static tok/s")
    args = ap.parse_args()

    rows = run(print, n_requests=args.requests, seed=args.seed)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        payload = {
            "us_per_call": {name: us for name, us, _ in rows},
            "derived": {name: derived for name, _, derived in rows},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")
    speedup = dict((n, d) for n, _, d in rows)["serve/speedup"]
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"continuous batching speedup {speedup:.2f}x below the "
            f"--min-speedup {args.min_speedup}x gate"
        )


if __name__ == "__main__":
    main()
