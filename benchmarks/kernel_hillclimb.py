"""Kernel-level hillclimb of RBGP4MM (the paper's contribution) on the
analytic v5e roofline, with every tuned configuration validated bit-exact
against the pure-jnp oracle in interpret mode.

Workload: the paper's Table-2 setting (4096 x 4096 x 4096 SDMM) at 93.75%
sparsity.  Each iteration states a hypothesis, the predicted delta on the
dominant term, and the measured (model) delta; the chosen config at each
step seeds the next.  CSV rows: name,us_per_call,derived(=speedup vs dense).
"""
from __future__ import annotations

import numpy as np

from repro.core import RBGP4Layout, RBGP4Spec
from repro.kernels import KernelDims, rbgp4mm
from repro.kernels import ref as kref

from .kernel_model import estimate_dense, estimate_rbgp4mm

M = K = N = 4096
SP = 0.9375


def _spec(n_o, g_i, G, C, sp_o, sp_i):
    b_u, b_v = min(G, 8), min(C, 8)
    return RBGP4Spec(g_o=n_o, g_r=(G // b_u, C // b_v), g_i=g_i,
                     g_b=(b_u, b_v), sp_o=sp_o, sp_i=sp_i)


STEPS = [
    # (label, spec, hypothesis)
    ("it0: paper GPU config",
     _spec((32, 128), (32, 32), 4, 1, 0.75, 0.75),
     "baseline: the paper's V100-tuned factors (G=4, C=1) — tiny inner "
     "blocks underfill the MXU (u_rows 4/16, u_contract 16/128)"),
    ("it1: MXU-align inner block (G=16, C=128)",
     _spec((16, 8), (16, 4), 16, 128, 0.75, 0.75),
     "raising (G, C) from (4, 1) to (16, 128) lifts u_rows 0.25->1.0 and "
     "u_contract 0.125->1.0 -> compute term ~16x down; memory becomes "
     "dominant"),
    ("it2: grow TM 256 -> 1024 (I-tile reuse)",
     _spec((4, 8), (64, 4), 16, 128, 0.75, 0.75),
     "I-traffic ~ (1-sp_o)*K/TM per output row: TM 256->1024 cuts the "
     "dominant I term ~4x"),
    ("it3: shift sparsity outward (sp_o 0.875) at TM=512",
     _spec((8, 16), (32, 2), 16, 128, 0.875, 0.5),
     "paper Table-2 says outer sparsity is the cheap kind; BUT the 2-adic "
     "feasibility cap forces TM down to 512 to carry sp_o=0.875 -> "
     "(1-sp_o)/TM is unchanged; prediction: ~neutral (trade-off, not win)"),
    ("it4: widen N blocking 512 -> 2048",
     _spec((4, 8), (64, 4), 16, 128, 0.75, 0.75),
     "W is re-streamed once per N pass: BN 512->2048 cuts W traffic 4x "
     "(minor term; expect <10% total)"),
]


def run(print_fn=print) -> list[tuple]:
    dense = estimate_dense(M, K, N)
    print_fn(f"# RBGP4MM kernel hillclimb — {M}x{K}x{N} @ {SP:.4%} sparsity "
             f"(analytic v5e; dense = {dense.t_total_s*1e6:.1f} us)")
    out = []
    prev = None
    for label, spec, hyp in STEPS:
        bn = 2048 if "it4" in label else 512
        est = estimate_rbgp4mm(spec, N, block_n=bn)
        assert abs(spec.sparsity - SP) < 1e-9, (label, spec.sparsity)
        speed = dense.t_total_s / est.t_total_s
        delta = (f"{prev/est.t_total_s:4.2f}x vs prev" if prev else "  —  ")
        print_fn(f"\n{label}\n  hypothesis: {hyp}")
        print_fn(f"  compute {est.t_compute_s*1e6:8.1f} us | memory "
                 f"{est.t_memory_s*1e6:8.1f} us (W {est.bytes_w/1e6:.0f} + I "
                 f"{est.bytes_i/1e6:.0f} + O {est.bytes_o/1e6:.0f} MB) | "
                 f"total {est.t_total_s*1e6:8.1f} us "
                 f"({speed:4.1f}x vs dense, {delta})")
        out.append((f"kernel_hillclimb,{label.split(':')[0]}",
                    est.t_total_s * 1e6, speed))
        prev = est.t_total_s
    # it5: the block-N search from it4 now lives in-tree — resolve the same
    # workload through repro.kernels.autotune (what block_n="auto" does at
    # every sparse_linear call site) and report the cached pick
    from repro.kernels import autotune

    dims_f = KernelDims.from_layout(RBGP4Layout(STEPS[-1][1]))
    tuned = autotune.autotune(dims_f, N, dtype="bfloat16", kind="rhs",
                              platform="v5e-model")
    est_t = estimate_rbgp4mm(STEPS[-1][1], N, block_n=tuned.block_n)
    print_fn(f"\nit5: kernels/autotune.py pick (block_n={tuned.block_n}, "
             f"order={tuned.grid_order}, source={tuned.source}) — the same "
             f"search block_n='auto' resolves through at model build time")
    print_fn(f"  total {est_t.t_total_s*1e6:8.1f} us "
             f"({dense.t_total_s/est_t.t_total_s:4.1f}x vs dense)")
    out.append(("kernel_hillclimb,it5_autotuned", est_t.t_total_s * 1e6,
                dense.t_total_s / est_t.t_total_s))

    # correctness gate: the tuned config must match the oracle exactly
    spec = STEPS[-1][1]
    lay = RBGP4Layout(spec)
    import jax, jax.numpy as jnp

    dims = KernelDims.from_layout(lay)
    key1, key2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(key1, lay.data_shape, jnp.float32) * 0.05
    x = jax.random.normal(key2, (K, 64), jnp.float32)
    got = rbgp4mm(dims, jnp.asarray(lay.adj_o), w, x, interpret=True,
                  block_n=64)
    want = kref.ref_rbgp4mm(lay, w, x)
    err = float(jnp.abs(got - want).max())
    print_fn(f"\ncorrectness (tuned config vs oracle, interpret): "
             f"max err {err:.2e}")
    assert err < 1e-4
    final = estimate_rbgp4mm(STEPS[-1][1], N, block_n=2048)
    frac = final.t_compute_s / final.t_total_s
    print_fn(f"final roofline fraction (compute/total): {frac:.2f} "
             f"({dense.t_total_s/final.t_total_s:.1f}x vs dense; paper "
             f"reports 9.2x vs cuBLAS at this sparsity on V100)")
    return out


if __name__ == "__main__":
    run()
