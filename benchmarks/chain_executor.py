"""Deep-chain executor: masked emulation vs blocked-CSR chain storage.

The scenario the chain subsystem exists for: a *hierarchical block* plan
(Vooturi et al. 2018 — dense outer blocking around multiple sparse
Ramanujan factors) on the tinyllama-1.1b projection shapes.  Such chains
have more than two sparse factors, so they are not RBGP4-expressible;
before the chain executor they ran as masked emulation — a dense (M, K)
trainable array *plus* a materialized (M, K) uint8 mask, at dense-matmul
speed.

Two comparisons per the paper's storage/runtime split:

  * **bytes** (the acceptance gate): chain index+value storage (values at
    non-zero blocks + per-factor adjacency lists) vs the masked container's
    mask+value bytes, aggregated over every sparsified layer of the plan.
    Gate: chain < 25% of masked.
  * **tok/s** (analytic v5e roofline, per the harness convention): the
    chainmm kernel touches only stored blocks (head tiles skipped at the
    grid level, dense leaf blocks on the MXU) while masked emulation pays
    full dense FLOPs and full dense weight traffic.

Correctness gates (CPU, every run):

  * the ``chain`` backend is **bit-identical** to the masked reference
    (forward + VJP) at a reduced shape — the parity anchor;
  * the interpret-mode Pallas ``chainmm_rhs`` / ``chain_sddmm_rhs``
    kernels match the dense oracle to 1e-4.

CSV rows: name,us_per_call,derived (derived = speedup for time rows,
byte ratio for the storage row).
"""
from __future__ import annotations

import numpy as np

from repro.obs import SCHEMA_VERSION  # benchmarks.run gates on this

ARCH = "tinyllama-1.1b"
SPARSITY = 0.875  # 3 pow-2 steps: one per Ramanujan factor at d_model
N_TOKENS = 2048
# hierarchical block chain: dense 4x4 outer blocking around three
# Ramanujan factors, with a dense 8x8 leaf sized for MXU packing (a tiny
# leaf is honest-roofline slower than dense — small output lanes)
HIER = (("complete", 4, 4, 0.0), ("ramanujan", 0, 0, -1.0),
        ("ramanujan", 0, 0, -1.0), ("ramanujan", 0, 0, -1.0),
        ("complete", 8, 8, 0.0))
MIN_DIM = 256


def run(print_fn=print) -> list[tuple]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import ChainLayout, design_rbgp
    from repro.kernels import autotune, chainmm as C
    from repro.kernels.perf_model import estimate_chainmm, estimate_dense
    from repro.sparsity import (
        PatternSpec,
        SparsityPlan,
        chain_storage_bytes,
        chain_weight,
        dense_weight,
        model_matmul_shapes,
        sparse_linear,
    )
    from repro.sparsity.api import MaskedWeight

    # -- the tinyllama hierarchical-block plan ------------------------------
    spec = PatternSpec(pattern="rbgp", sparsity=SPARSITY, backend="auto",
                       factors=HIER, min_dim=MIN_DIM)
    assert spec.is_chain() and spec.storage() == "chain"
    plan = SparsityPlan.uniform(spec, note="hierarchical-block chain")
    shapes = model_matmul_shapes(get_config(ARCH))

    # -- storage: chain index+value vs masked mask+value --------------------
    chain_bytes = masked_bytes = 0
    n_sparse = 0
    layouts: dict[tuple, ChainLayout] = {}
    for path in sorted(shapes):
        m, k, c = shapes[path]
        if not spec.applies_to(m, k):
            continue
        key = (m, k)
        if key not in layouts:
            layouts[key] = ChainLayout(
                design_rbgp(m, k, SPARSITY, factors=HIER, seed=0))
        rep = chain_storage_bytes(layouts[key])
        chain_bytes += rep["chain_total"] * c
        masked_bytes += rep["masked_total"] * c
        n_sparse += c
    ratio = chain_bytes / masked_bytes
    print_fn(f"# {ARCH} hierarchical-block plan: {n_sparse} sparsified "
             f"projections @ {SPARSITY:.4%} sparsity "
             f"({len(layouts)} distinct shapes)")
    print_fn(f"  masked storage: {masked_bytes/2**20:9.1f} MiB "
             f"(dense f32 values + full uint8 mask)")
    print_fn(f"  chain  storage: {chain_bytes/2**20:9.1f} MiB "
             f"(non-zero values + per-factor indices) "
             f"-> {ratio:.1%} of masked")
    assert ratio < 0.25, f"chain storage {ratio:.1%} >= 25% of masked"

    # -- runtime: analytic roofline at N_TOKENS tokens ----------------------
    t_masked = t_chain = 0.0
    for (m, k), lay in sorted(layouts.items()):
        count = sum(c for p, (mm, kk, c) in shapes.items()
                    if (mm, kk) == (m, k) and spec.applies_to(mm, kk))
        dims = C.chain_dims(lay)
        tuned = autotune.autotune(dims, N_TOKENS, dtype="bfloat16",
                                  kind="chain_rhs", platform="v5e-model")
        t_chain += estimate_chainmm(
            dims, N_TOKENS, block_n=tuned.block_n).t_total_s * count
        # masked emulation: the mask zeroes values, not work
        t_masked += estimate_dense(m, k, N_TOKENS).t_total_s * count
    speed = t_masked / t_chain
    tok_masked = N_TOKENS / t_masked
    tok_chain = N_TOKENS / t_chain
    print_fn(f"  masked emulation: {t_masked*1e6:9.1f} us/layer-pass "
             f"({tok_masked:,.0f} tok/s through the sparse projections)")
    print_fn(f"  chain executor  : {t_chain*1e6:9.1f} us/layer-pass "
             f"({tok_chain:,.0f} tok/s, {speed:.1f}x)")

    # -- parity gates (reduced shape, CPU) ----------------------------------
    lay_s = ChainLayout(design_rbgp(256, 512, 0.875, factors=HIER, seed=1))
    dims_s = C.chain_dims(lay_s)
    kw, kx, kg = jax.random.split(jax.random.PRNGKey(0), 3)
    w = chain_weight(kw, lay_s)
    x = jax.random.normal(kx, (24, 512), jnp.float32)
    g = jax.random.normal(kg, (24, 256), jnp.float32)

    # bit parity: chain backend == masked reference, forward and VJP
    wm = MaskedWeight(w=dense_weight(w), mask=jnp.asarray(lay_s.mask()))
    y_c, pull_c = jax.vjp(
        lambda wd, x: sparse_linear(
            type(w)(w_data=wd, layout=lay_s), x, backend="chain"),
        w.w_data, x)
    y_m, pull_m = jax.vjp(
        lambda wd, x: sparse_linear(
            MaskedWeight(w=wd, mask=wm.mask), x, backend="xla_masked"),
        wm.w, x)
    assert (np.asarray(y_c) == np.asarray(y_m)).all()
    (gw_c, gx_c), (gw_m, gx_m) = pull_c(g), pull_m(g)
    assert (np.asarray(gx_c) == np.asarray(gx_m)).all()
    assert (np.asarray(gw_c)
            == np.asarray(C.chain_pack_compact(lay_s, gw_m))).all()
    print_fn("  parity: chain backend bit-identical to masked reference "
             "(fwd + VJP)")

    # kernel parity: interpret-mode Pallas vs dense oracle
    adj = jnp.asarray(lay_s.adjs[0])
    y_pl = C.chainmm_rhs(dims_s, adj, x, w.w_data, interpret=True)
    err_f = float(jnp.abs(y_pl - x @ C.chain_unpack_dense(
        lay_s, w.w_data).T).max())
    dw_pl = C.chain_sddmm_rhs(dims_s, adj, g, x, interpret=True)
    err_b = float(jnp.abs(dw_pl - C.chain_pack_compact(
        lay_s, g.T @ x)).max())
    print_fn(f"  kernels (interpret): chainmm_rhs max err {err_f:.2e}, "
             f"chain_sddmm_rhs max err {err_b:.2e}")
    assert err_f < 1e-4 and err_b < 1e-4

    return [
        ("chain_executor,masked_emulation", t_masked * 1e6, 1.0),
        ("chain_executor,chain", t_chain * 1e6, speed),
        ("chain_executor,storage_ratio", 0.0, ratio),
    ]


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write rows as {name: us} + derived map")
    args = ap.parse_args()
    rows = run()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        from repro.obs import bench_payload

        with open(args.json, "w") as f:
            json.dump(bench_payload(rows), f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json} "
              f"(schema v{SCHEMA_VERSION})")
