"""MoE expert execution: masked-dense einsum vs the batched compact kernel.

The paper's runtime claim applied to stacked experts: a token-choice MoE
layer holds E copies of each FFN projection.  The masked training path
executes them as E *dense* masked matmuls ((W*mask) einsum — full dense
FLOPs and full dense weight traffic regardless of sparsity), while the
batched compact path (``rbgp4mm_rhs_stacked``) runs ONE Pallas launch
whose grid covers ``(expert, token-tile, row-tile, k)`` and touches only
the 2|E| compact values and the d_o non-zero input tiles.

Production shape (Qwen2-MoE-A2.7B-ish): E=64 experts, d_model=2048,
d_expert=1408->1024 (pow2-friendly), C=256 tokens routed per expert,
93.75% sparsity.  Time axis = the analytic v5e roofline model
(``repro.kernels.perf_model``) per the harness convention; correctness of
the compact path is gated bit-level against the masked-dense oracle in
interpret mode at a reduced shape (the same parity the test suite checks).

CSV rows: name,us_per_call,derived(=speedup of batched-compact vs
masked-dense at the same shape).
"""
from __future__ import annotations

import numpy as np

E = 64
D_MODEL = 2048
D_EXPERT = 1024
TOK_PER_E = 256
SPARSITY = 0.9375


def run(print_fn=print) -> list[tuple]:
    import jax
    import jax.numpy as jnp

    from repro.core import design_rbgp4, RBGP4Layout
    from repro.kernels import (KernelDims, autotune, kernel_dims,
                               rbgp4mm_rhs_stacked, ref)
    from repro.kernels.perf_model import estimate_dense, estimate_rbgp4mm

    # -- analytic production-shape comparison (v5e roofline) ----------------
    spec = design_rbgp4(D_EXPERT, D_MODEL, SPARSITY)
    dense_one = estimate_dense(D_EXPERT, D_MODEL, TOK_PER_E)
    # masked-dense pays full dense time per expert (the mask zeroes values,
    # not work); E experts execute as E einsum instances
    t_masked = dense_one.t_total_s * E
    dims = KernelDims.from_layout(RBGP4Layout(spec))
    tuned = autotune.autotune(
        dims, TOK_PER_E, dtype="bfloat16", kind="rhs", platform="v5e-model"
    )
    comp_one = estimate_rbgp4mm(spec, TOK_PER_E, block_n=tuned.block_n)
    t_compact = comp_one.t_total_s * E
    speed = t_masked / t_compact
    print_fn(
        f"# stacked experts, E={E} x ({D_EXPERT}x{D_MODEL}) @ "
        f"{SPARSITY:.4%} sparsity, {TOK_PER_E} tokens/expert "
        f"(autotuned block_n={tuned.block_n})"
    )
    print_fn(f"  masked-dense : {t_masked*1e6:9.1f} us  (E dense einsums; "
             f"dense FLOPs + dense weight traffic)")
    print_fn(f"  batched-compact: {t_compact*1e6:7.1f} us  (one stacked "
             f"launch; {speed:.1f}x)")
    rows = [
        ("stacked_experts,masked_dense", t_masked * 1e6, 1.0),
        ("stacked_experts,batched_compact", t_compact * 1e6, speed),
    ]

    # -- correctness gate: interpret-mode parity at a reduced shape ---------
    spec_s = design_rbgp4(256, 128, 0.75)
    lay = RBGP4Layout(spec_s)
    dims_s = kernel_dims(lay)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    e_s = 4
    w = jax.random.normal(k1, (e_s,) + lay.data_shape, jnp.float32) * 0.05
    x = jax.random.normal(k2, (e_s, 24, 128), jnp.float32)
    got = rbgp4mm_rhs_stacked(dims_s, jnp.asarray(lay.adj_o), x, w,
                              interpret=True, block_n=8)
    want = jnp.einsum(
        "enk,emk->enm", x,
        jax.vmap(lambda wd: ref.unpack_dense(lay, wd))(w),
    )
    err = float(jnp.abs(got - want).max())
    print_fn(f"  correctness (batched-compact vs masked-dense oracle, "
             f"interpret): max err {err:.2e}")
    assert err < 1e-4
    return rows


if __name__ == "__main__":
    run()
