"""Sharded serving benchmark: continuous vs TP-sharded vs disaggregated.

Runs the PR 3 continuous-batching loop and the PR 6 mesh engines over the
same mixed-length workload on a forced 4-device CPU mesh (the same code
path lays out real TPU meshes) and reports:

  * tokens/sec per engine (full drain wall clock after a warmup pass, so
    jit compilation is excluded);
  * per-role occupancy: decode-row occupancy (busy decode rows / slot
    capacity over every decode step), chunked-prefill chunk count for the
    sharded engine, and KV-page handoffs for the disaggregated engine;
  * a parity gate: every engine's greedy tokens must replay the
    ``run_sequential`` oracle *run with that engine's own params* — the
    sharded engines share their weight layout with the oracle, which is
    the exact-replay contract tests/test_serve_sharded.py pins.

CSV rows: name,us_per_call(=us per generated token),derived.
Standalone:
  PYTHONPATH=src python -m benchmarks.serve_sharded --json SERVE_SHARDED.json
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _ensure_multi_device(n: int = 4) -> None:
    """Force ``n`` host CPU devices — must run before jax initializes."""
    import sys

    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={n}")


_ensure_multi_device()

N_REQUESTS = 12
PROMPT_LENS = (8, 16, 24, 32)
GEN_LENS = (4, 8, 16)
PAGE = 4
SLOTS = 4
CHUNK = 8
SEED = 0


def _build(seed):
    import jax

    from repro.configs import apply_sparsity, get_config, reduce_config
    from repro.models import LMModel

    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5, backend="auto",
                         min_dim=64)
    model = LMModel(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _workload(cfg, n_requests, seed):
    from repro.data import RequestStream

    return RequestStream(cfg.vocab_size, n_requests,
                         prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS,
                         seed=seed).requests()


def _make(kind, model, params, max_len):
    import jax

    from repro.launch.mesh import make_serve_mesh
    from repro.serve import make_engine

    kw = dict(page_size=PAGE, max_slots=SLOTS, max_request_len=max_len)
    if kind == "continuous":
        return make_engine("continuous", model, params, **kw)
    if kind == "sharded":
        return make_engine("sharded", model, params,
                           mesh=make_serve_mesh(2, 2),
                           prefill_chunk=CHUNK, **kw)
    devs = jax.devices()
    return make_engine("disagg", model, params,
                       prefill_mesh=make_serve_mesh(1, 2,
                                                    devices=devs[:2]),
                       decode_mesh=make_serve_mesh(1, 2,
                                                   devices=devs[2:]),
                       **kw)


def _drain(kind, model, params, workload, max_len):
    eng = _make(kind, model, params, max_len)
    for r in workload:
        eng.submit(r["prompt"], r["max_new_tokens"])
    t0 = time.perf_counter()
    out = eng.drain()
    return eng, out, time.perf_counter() - t0


def run(print_fn=print, n_requests: int = N_REQUESTS,
        seed: int = SEED) -> list[tuple]:
    import jax

    from repro.serve import run_sequential

    n_dev = len(jax.devices())
    if n_dev < 4:
        print_fn(f"# serve_sharded: only {n_dev} device(s) — jax was "
                 f"initialized before the forced-host-device flag could "
                 f"apply; skipping (run standalone: python -m "
                 f"benchmarks.serve_sharded)")
        return []

    model, params = _build(seed)
    workload = _workload(model.cfg, n_requests, seed)
    max_len = max(r["prompt"].shape[0] + r["max_new_tokens"]
                  for r in workload)
    n_gen = sum(r["max_new_tokens"] for r in workload)
    print_fn(f"# workload: {len(workload)} requests, prompts "
             f"{PROMPT_LENS}, gens {GEN_LENS}, {n_gen} new tokens total; "
             f"{n_dev} devices")

    rows = []
    for kind in ("continuous", "sharded", "disagg"):
        _drain(kind, model, params, workload, max_len)   # warmup: compile
        eng, out, wall = _drain(kind, model, params, workload, max_len)
        # parity gate: replay the sequential oracle over the engine's own
        # (possibly sharded) params — bit-identical greedy tokens
        ref = run_sequential(model, eng.params, workload,
                             cache_len=eng.gather_tokens)
        for r in workload:
            rid = r["rid"]
            assert (out[rid] == ref[rid]).all(), (
                f"{kind}: greedy tokens diverge from the sequential "
                f"oracle for request {rid}")
        st = eng.stats
        occ = (st["decode_row_steps"]
               / max(st["decode_steps"] * SLOTS, 1))
        extra = ""
        if kind == "sharded":
            assert all(t["prefill_chunks"] <= 1 for t in eng.step_trace)
            extra = f", {int(st['prefill_chunks'])} prefill chunks"
            rows.append(("serve_sharded/prefill_chunks", 0.0,
                         st["prefill_chunks"]))
        if kind == "disagg":
            extra = f", {int(st['handoffs'])} KV handoffs"
            rows.append(("serve_sharded/handoffs", 0.0, st["handoffs"]))
        print_fn(f"# {kind:10s}: {n_gen} tokens in {wall*1e3:7.0f} ms "
                 f"-> {n_gen/wall:6.0f} tok/s, decode-row occupancy "
                 f"{occ:.1%}{extra}")
        rows.append((f"serve_sharded/{kind}_tok", wall / n_gen * 1e6,
                     n_gen / wall))
        rows.append((f"serve_sharded/{kind}_decode_occupancy", 0.0, occ))
    print_fn("# parity gate passed: every engine replays its oracle "
             "token-for-token")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="",
                    help="write rows as a name -> us_per_call/derived map")
    args = ap.parse_args()

    rows = run(print, n_requests=args.requests, seed=args.seed)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        payload = {
            "us_per_call": {name: us for name, us, _ in rows},
            "derived": {name: derived for name, _, derived in rows},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
