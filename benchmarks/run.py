"""Benchmark harness: one module per paper table + the roofline report.

Prints ``name,us_per_call,derived`` CSV per the harness contract, plus
human-readable tables in '#'-prefixed prose lines.

Usage:
  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --train 40 # + accuracy parity
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", type=int, default=40,
                    help="steps for the Table-1 accuracy-parity run (0=off)")
    ap.add_argument("--dryrun-path", default="results/dryrun_optimized.jsonl")
    args = ap.parse_args()

    from . import kernel_hillclimb, roofline, table1_models, \
        table2_sparsity_dist, table3_row_repetition

    rows: list[tuple] = []
    print("# === Table 1 (paper: accuracy/mem/time per model x pattern) ===")
    rows += table1_models.run(print, train_steps=args.train)
    print("\n# === Table 2 (paper: sparsity split between G_o and G_i) ===")
    rows += table2_sparsity_dist.run(print)
    print("\n# === Table 3 (paper: row repetition via G_r/G_b) ===")
    rows += table3_row_repetition.run(print)
    print("\n# === Kernel hillclimb (EXPERIMENTS.md section Perf) ===")
    rows += kernel_hillclimb.run(print)
    print("\n# === Roofline (dry-run derived; see EXPERIMENTS.md) ===")
    rows += roofline.run(print, path=args.dryrun_path)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")


if __name__ == "__main__":
    main()
