"""Benchmark harness: one module per paper table + the roofline report.

Prints ``name,us_per_call,derived`` CSV per the harness contract, plus
human-readable tables in '#'-prefixed prose lines.

Usage:
  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --train 40 # + accuracy parity
  PYTHONPATH=src python -m benchmarks.run --train 0 \
      --only kernels,stacked --json BENCH_ci.json    # CI smoke subset

``--json PATH`` additionally writes the rows as a ``{name: us_per_call}``
map (plus a ``derived`` sub-map), so the perf trajectory is
machine-readable across PRs (CI uploads ``BENCH_<rev>.json`` artifacts).
The payload carries the shared observability schema version
(``repro.obs.SCHEMA_VERSION``) and, when any section resolved kernel
launch configs through the autotuner, a ``kernel_roofline`` table of
modeled-vs-measured per-shape timings (``repro.obs.kernelstats``).
"""
from __future__ import annotations

import argparse
import json


SECTIONS = ("table1", "table2", "plan", "table3", "kernels", "stacked",
            "chain", "quant", "serve", "serve_sharded", "serve_faults",
            "prefix", "roofline")


def _require_schema(mod, section: str) -> None:
    """quant/chain artifacts feed cross-PR tooling: refuse to run a
    section whose module no longer declares the shared schema version."""
    if not hasattr(mod, "SCHEMA_VERSION"):
        raise SystemExit(
            f"--only {section}: benchmarks module {mod.__name__} has no "
            f"SCHEMA_VERSION — its JSON artifact would be unversioned; "
            f"re-export repro.obs.SCHEMA_VERSION from the module")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", type=int, default=40,
                    help="steps for the Table-1 accuracy-parity run (0=off)")
    ap.add_argument("--dryrun-path", default="results/dryrun_optimized.jsonl")
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {SECTIONS}")
    ap.add_argument("--json", default="",
                    help="also write rows as a name -> us_per_call JSON map")
    args = ap.parse_args()

    only = {s.strip() for s in args.only.split(",") if s.strip()}
    unknown = only - set(SECTIONS)
    if unknown:
        raise SystemExit(f"--only: unknown sections {sorted(unknown)}; "
                         f"have {SECTIONS}")

    def want(section: str) -> bool:
        return not only or section in only

    # record every autotuner resolution the sections trigger, so the JSON
    # artifact can embed the modeled-vs-measured roofline table
    from repro.obs import kernelstats

    kernelstats.enable()

    rows: list[tuple] = []
    if want("table1"):
        from . import table1_models

        print("# === Table 1 (paper: accuracy/mem/time per model x pattern) ===")
        rows += table1_models.run(print, train_steps=args.train)
    if want("table2"):
        from . import table2_sparsity_dist

        print("\n# === Table 2 (paper: sparsity split between G_o and G_i) ===")
        rows += table2_sparsity_dist.run(print)
    if want("plan"):
        from . import table2_sparsity_dist

        print("\n# === Plan solver (per-layer sparsity distribution) ===")
        rows += table2_sparsity_dist.run_plan(print)
    if want("table3"):
        from . import table3_row_repetition

        print("\n# === Table 3 (paper: row repetition via G_r/G_b) ===")
        rows += table3_row_repetition.run(print)
    if want("kernels"):
        from . import kernel_hillclimb

        print("\n# === Kernel hillclimb (EXPERIMENTS.md section Perf) ===")
        rows += kernel_hillclimb.run(print)
    if want("stacked"):
        from . import stacked_experts

        print("\n# === Stacked experts (masked-dense vs batched-compact) ===")
        rows += stacked_experts.run(print)
    if want("chain"):
        from . import chain_executor

        _require_schema(chain_executor, "chain")
        print("\n# === Chain executor (masked emulation vs blocked-CSR) ===")
        rows += chain_executor.run(print)
    if want("quant"):
        from . import quant_kernels

        _require_schema(quant_kernels, "quant")
        print("\n# === Quantized storage (int8 leaf blocks + block scales) ===")
        rows += quant_kernels.run(print)
    if want("serve"):
        from . import serve_engine

        print("\n# === Serving (static vs continuous batching, paged KV) ===")
        rows += serve_engine.run(print)
    if want("serve_sharded"):
        from . import serve_sharded

        print("\n# === Sharded serving (continuous vs TP mesh vs disagg) ===")
        rows += serve_sharded.run(print)
    if want("serve_faults"):
        from . import serve_faults

        print("\n# === Fault soak (seeded fault schedules, recompute parity) ===")
        rows += serve_faults.run(print)
    if want("prefix"):
        from . import serve_prefix

        print("\n# === Prefix sharing (refcounted COW pages + radix index) ===")
        rows += serve_prefix.run(print)
    if want("roofline"):
        from . import roofline

        print("\n# === Roofline (dry-run derived; see EXPERIMENTS.md) ===")
        rows += roofline.run(print, path=args.dryrun_path)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")

    if args.json:
        from repro.obs import bench_payload

        extra = {}
        if kernelstats.records():
            extra["kernel_roofline"] = kernelstats.report()
        payload = bench_payload(rows, **extra)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json} "
              f"(schema v{payload['schema_version']}, "
              f"{len(kernelstats.records())} kernel-roofline records)")


if __name__ == "__main__":
    main()
