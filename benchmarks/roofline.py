"""Roofline report: renders the dry-run JSONL into the EXPERIMENTS.md tables.

Per (arch x shape x mesh): the three roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), per-device memory,
and a one-line "what would move the dominant term" note.
"""
from __future__ import annotations

import json
import os
import sys
from collections import defaultdict

DEFAULT_PATH = "results/dryrun_optimized.jsonl"

ADVICE = {
    "compute_s": "raise MXU utilization / cut redundant matmul work "
                 "(remat policy, attention formulation)",
    "memory_s": "cut HBM traffic: fuse attention (Pallas flash kernel), "
                "bigger fusion tiles, bf16 intermediates",
    "collective_s": "reshard: reduce TP all-reduce points, overlap "
                    "collectives, compress gradients",
}


def load(path: str = DEFAULT_PATH) -> list[dict]:
    recs = []
    if not os.path.exists(path):
        return recs
    seen = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def fmt_row(r: dict) -> str:
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"skipped | — | — | — | — | — |")
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"ERROR | — | — | — | — | {r.get('error', '')[:60]} |")
    t = r["roofline"]
    dom = r["bottleneck"].replace("_s", "")
    ratio = r.get("useful_flop_ratio")
    ratio_s = f"{ratio:.2f}" if ratio else "—"
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
        f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
        f"| {t['collective_s']:.3f} | {dom} | {ratio_s} "
        f"| {r['memory']['peak_per_device_gb']:.1f} |"
    )


def run(print_fn=print, path: str = DEFAULT_PATH) -> list[tuple]:
    recs = load(path)
    if not recs:
        print_fn(f"# roofline: no dry-run records at {path} — run "
                 f"`python -m repro.launch.dryrun --all --mesh both` first")
        return []
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    print_fn("| arch | shape | mesh | status | compute s | memory s "
             "| collective s | bottleneck | useful | mem/dev GB |")
    print_fn("|---|---|---|---|---|---|---|---|---|---|")
    out = []
    n_ok = n_skip = n_err = 0
    for r in recs:
        print_fn(fmt_row(r))
        if r.get("status") == "ok":
            n_ok += 1
            t = r["roofline"]
            dom_t = max(t.values())
            out.append((f"roofline,{r['arch']},{r['shape']},{r['mesh']}",
                        dom_t * 1e6, r.get("useful_flop_ratio") or 0.0))
        elif r.get("status") == "skipped":
            n_skip += 1
        else:
            n_err += 1
    print_fn(f"\ncells: {n_ok} ok, {n_skip} skipped (documented), "
             f"{n_err} errors")

    # bottleneck distribution + hillclimb candidates
    dom_count = defaultdict(int)
    worst = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        dom_count[r["bottleneck"]] += 1
        t = r["roofline"]
        ideal = t["compute_s"]
        actual = max(t.values())
        frac = ideal / actual if actual else 0
        worst.append((frac, r["arch"], r["shape"], r["mesh"], r["bottleneck"]))
    print_fn(f"\nbottlenecks: {dict(dom_count)}")
    worst.sort()
    print_fn("\nlowest roofline fraction (compute_term / dominant_term):")
    for frac, arch, shape, mesh, dom in worst[:6]:
        print_fn(f"  {frac:6.3f}  {arch:22s} {shape:12s} {mesh:8s} "
                 f"[{dom}] -> {ADVICE[dom]}")
    return out


if __name__ == "__main__":
    run(path=sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH)
