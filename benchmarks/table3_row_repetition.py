"""Paper Table 3: effect of row repetition (sizes of G_r and G_b).

Fixed G_t = G_r (x) G_i (x) G_b of size (128, 32), sp(G_o) = 50%; the
repetition amount |G_r.U| * |G_b.U| varies.  On GPU this controls register
reuse; on TPU the same knob sets the dense sub-matmul's row count G and
chunk width C, i.e. MXU sublane/lane packing (DESIGN.md §2) — the trend
(more repetition -> faster) carries over with a different mechanism.

Output CSV: name,us_per_call,derived (derived = speedup vs (1,1)/(1,1)).
"""
from __future__ import annotations

from repro.core import RBGP4Spec

from .kernel_model import estimate_rbgp4mm

# paper Table 3 rows: (G_r, G_b) sizes; G_t fixed at (128, 32)
ROWS = [
    ((1, 1), (1, 1)),
    ((2, 1), (1, 1)),
    ((4, 1), (1, 1)),
    ((1, 1), (2, 1)),
    ((1, 1), (4, 1)),
    ((2, 1), (2, 1)),
    # TPU-native points beyond the paper (MXU-aligned repetition)
    ((8, 2), (2, 2)),
    ((16, 4), (1, 1)),
]

N = 4096
SPARSITIES = (0.75, 0.875, 0.9375)


def spec_for(g_r, g_b, sp):
    # G_t = G_r x G_i x G_b must be (128, 32); G_o brings the total to 4096^2
    gi_u = 128 // (g_r[0] * g_b[0])
    gi_v = 32 // (g_r[1] * g_b[1])
    # G_o carries 50% sparsity; G_i the rest
    sp_i = 1.0 - (1.0 - sp) * 2.0
    return RBGP4Spec(
        g_o=(4096 // 128, 4096 // 32),
        g_r=g_r, g_i=(gi_u, gi_v), g_b=g_b,
        sp_o=0.5, sp_i=sp_i,
    )


def run(print_fn=print) -> list[tuple]:
    out = []
    print_fn("# Table 3: row repetition via G_r/G_b sizes "
             "(G_t=(128,32), sp_o=50%, analytic v5e model)")
    print_fn(f"{'G_r':>8} {'G_b':>8} {'rep':>4} | " +
             " | ".join(f"sp={s}" for s in SPARSITIES))
    base_t = {}
    for g_r, g_b in ROWS:
        rep = g_r[0] * g_b[0]
        times = []
        for sp in SPARSITIES:
            est = estimate_rbgp4mm(spec_for(g_r, g_b, sp), N)
            times.append(est.t_total_s)
            base_t.setdefault(sp, est.t_total_s if (g_r, g_b) == ((1, 1), (1, 1)) else None)
            if base_t[sp] is None and (g_r, g_b) == ((1, 1), (1, 1)):
                base_t[sp] = est.t_total_s
        name = f"table3,gr={g_r},gb={g_b}"
        derived = base_t[SPARSITIES[0]] / times[0] if base_t[SPARSITIES[0]] else 1.0
        out.append((name, times[0] * 1e6, derived))
        print_fn(f"{str(g_r):>8} {str(g_b):>8} {rep:>4} | " +
                 " | ".join(f"{t*1e6:7.1f}us" for t in times))
    # trend: repetition 4 beats repetition 1 at every sparsity
    for si, sp in enumerate(SPARSITIES):
        t1 = estimate_rbgp4mm(spec_for((1, 1), (1, 1), sp), N).t_total_s
        t4 = estimate_rbgp4mm(spec_for((4, 1), (1, 1), sp), N).t_total_s
        assert t4 <= t1, f"Table-3 trend violated at sp={sp}"
    print_fn("\ntrend check OK: more row repetition -> faster "
             "(paper Table 3 reproduced; TPU rows show MXU-aligned configs)")
    return out


if __name__ == "__main__":
    run()
