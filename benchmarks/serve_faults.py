"""Fault-injection soak: seeded fault schedules against the serving engine.

Runs the continuous engine with ``reserve="prompt"`` oversubscription on a
deliberately tight page pool under N seeded :class:`FaultSchedule.random`
schedules (capacity drops/restores, transient allocation failures, step
delays, request kills) with allocator invariant checks armed
(``REPRO_SERVE_CHECKS=1``), and gates every run on the robustness
contract:

  * every request reaches a terminal lifecycle state (no stalls, no
    leaks — the drain either finishes or the watchdog would have raised);
  * every request that still FINISHED produced tokens bit-identical to
    the no-fault baseline run (preemption/kill recompute is exact);
  * the allocator is whole afterwards: zero allocated blocks, free +
    quarantined partitions the pool, ``check_invariants()`` passes.

CSV rows: name,us_per_call(=us per generated token),derived.
Standalone:
  PYTHONPATH=src python -m benchmarks.serve_faults --json SERVE_FAULTS.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

N_SCHEDULES = 20
SHAPES = [(4, 8), (12, 10), (8, 9), (16, 6), (6, 10)]
PAGE = 4
SLOTS = 4
MAX_LEN = 40
N_BLOCKS = 13
SEED = 0


def _build(seed):
    import jax

    from repro.configs import apply_sparsity, get_config, reduce_config
    from repro.models import LMModel

    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5, backend="auto",
                         min_dim=64)
    model = LMModel(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _workload(cfg, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [
        {"rid": i, "prompt": rng.integers(
            0, cfg.vocab_size, s).astype(np.int32),
         "max_new_tokens": g}
        for i, (s, g) in enumerate(SHAPES)
    ]


def _drain(model, params, workload, faults=None):
    from repro.serve import ContinuousEngine

    eng = ContinuousEngine(model, params, page_size=PAGE, max_slots=SLOTS,
                           max_request_len=MAX_LEN, reserve="prompt",
                           n_blocks=N_BLOCKS, faults=faults,
                           preempt_backoff=0)
    for r in workload:
        eng.submit(r["prompt"], r["max_new_tokens"])
    t0 = time.perf_counter()
    out = eng.drain()
    return eng, out, time.perf_counter() - t0


def run(print_fn=print, n_schedules: int = N_SCHEDULES,
        seed: int = SEED) -> list[tuple]:
    os.environ["REPRO_SERVE_CHECKS"] = "1"

    from repro.serve import FINISHED, TERMINAL_STATES, FaultSchedule

    model, params = _build(seed)
    workload = _workload(model.cfg, seed)
    n_gen = sum(g for _, g in SHAPES)
    print_fn(f"# workload: {len(workload)} requests, {n_gen} new tokens; "
             f"pool {N_BLOCKS} blocks x {PAGE} tokens, reserve=prompt; "
             f"{n_schedules} fault schedules, invariant checks ON")

    # the no-fault reference outputs (also warms the shared jit cache)
    base_eng, baseline, _ = _drain(model, params, workload)
    assert all(r.state == FINISHED for r in base_eng.requests.values())

    totals = dict(preemptions=0, fault_kills=0, expired=0, failed=0,
                  resumed_prefills=0, fault_events=0, finished=0,
                  survivors_checked=0)
    wall = 0.0
    for s in range(n_schedules):
        faults = FaultSchedule.random(seed + s, horizon=32, n_events=5,
                                      max_drop=4)
        eng, out, dt = _drain(model, params, workload, faults=faults)
        wall += dt

        # gate 1: every request terminal
        bad = {r.rid: r.state for r in eng.requests.values()
               if r.state not in TERMINAL_STATES}
        assert not bad, f"schedule {s}: non-terminal requests {bad}"

        # gate 2: surviving outputs bit-identical to the no-fault run
        for req in eng.requests.values():
            if req.state == FINISHED:
                totals["finished"] += 1
                if (out[req.rid] != baseline[req.rid]).any():
                    raise AssertionError(
                        f"schedule {s}: request {req.rid} survived faults "
                        f"but diverged from the no-fault run")
                totals["survivors_checked"] += 1

        # gate 3: allocator conservation after the churn
        alloc = eng.kv.allocator
        alloc.check_invariants()
        assert alloc.n_allocated == 0, f"schedule {s}: leaked blocks"
        assert alloc.n_free + alloc.n_quarantined == N_BLOCKS - 1

        st = eng.stats
        for k in ("preemptions", "fault_kills", "expired", "failed",
                  "resumed_prefills", "fault_events"):
            totals[k] += int(st[k])
        print_fn(f"# schedule {s:2d} (seed {seed + s:2d}): "
                 f"{len(faults)} events, {int(st['preemptions'])} preempts, "
                 f"{int(st['fault_kills'])} kills, "
                 f"{int(st['failed'])} failed, "
                 f"{sum(1 for r in eng.requests.values() if r.state == FINISHED)}"
                 f"/{len(workload)} finished -> OK")

    print_fn(f"# soak passed: {n_schedules} schedules, "
             f"{totals['fault_events']} fault events, "
             f"{totals['preemptions']} preemptions, "
             f"{totals['fault_kills']} kills, all terminal, "
             f"{totals['survivors_checked']} survivor outputs bit-exact, "
             f"zero invariant violations")
    per_tok = wall / max(n_schedules * n_gen, 1) * 1e6
    return [
        ("serve_faults/soak_tok", per_tok, totals["finished"]),
        ("serve_faults/schedules", 0.0, n_schedules),
        ("serve_faults/fault_events", 0.0, totals["fault_events"]),
        ("serve_faults/preemptions", 0.0, totals["preemptions"]),
        ("serve_faults/fault_kills", 0.0, totals["fault_kills"]),
        ("serve_faults/failed", 0.0, totals["failed"]),
        ("serve_faults/expired", 0.0, totals["expired"]),
        ("serve_faults/resumed_prefills", 0.0, totals["resumed_prefills"]),
        ("serve_faults/survivors_checked", 0.0,
         totals["survivors_checked"]),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedules", type=int, default=N_SCHEDULES)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="",
                    help="write rows as a name -> us_per_call/derived map")
    args = ap.parse_args()

    rows = run(print, n_schedules=args.schedules, seed=args.seed)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        payload = {
            "us_per_call": {name: us for name, us, _ in rows},
            "derived": {name: derived for name, _, derived in rows},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
