"""Paper Table 2: effect of distributing sparsity between G_o and G_i —
plus the plan-level generalization: distributing sparsity *between layers*
with the SparsityPlan budget solver (``run_plan``; section ``plan`` in
``benchmarks/run.py --only``).

Fixed sizes (paper: O, W, I all 4096x4096; base graph sizes
G_o=(32,128), G_r=(4,1), G_i=(32,32), G_b=(1,1)); sparsity split varies.
The paper's observed trend — for fixed total sparsity, putting more
sparsity in G_o is faster (tile skipping removes whole memory loads) —
falls out of the kernel cost model's I-traffic term.

Output CSV: name,us_per_call,derived (derived = speedup over dense).
"""
from __future__ import annotations

from repro.core import RBGP4Spec

from .kernel_model import estimate_dense, estimate_rbgp4mm

# paper Table 2 rows: (total_sp, sp_o, sp_i)
ROWS = [
    (0.75, 0.0, 0.75),
    (0.75, 0.5, 0.5),
    (0.875, 0.0, 0.875),
    (0.875, 0.5, 0.75),
    (0.875, 0.75, 0.5),
    (0.9375, 0.0, 0.9375),
    (0.9375, 0.5, 0.875),
    (0.9375, 0.75, 0.75),
    (0.9375, 0.875, 0.5),
]

N = 4096


def spec_for(sp_o, sp_i):
    # paper sizes: G_o=(32,128) G_r=(4,1) G_i=(32,32) G_b=(1,1) -> 4096x4096
    return RBGP4Spec(g_o=(32, 128), g_r=(4, 1), g_i=(32, 32), g_b=(1, 1),
                     sp_o=sp_o, sp_i=sp_i)


def run(print_fn=print) -> list[tuple]:
    dense = estimate_dense(4096, 4096, N)
    out = [("table2,dense,0,0", dense.t_total_s * 1e6, 1.0)]
    print_fn("# Table 2: sparsity split between G_o and G_i "
             "(4096x4096x4096, analytic v5e model)")
    print_fn(f"dense: {dense.t_total_s*1e6:.1f} us  (paper: 11.2 ms on V100)")
    prev_sp = None
    for sp, sp_o, sp_i in ROWS:
        est = estimate_rbgp4mm(spec_for(sp_o, sp_i), N)
        speedup = dense.t_total_s / est.t_total_s
        name = f"table2,sp={sp},sp_o={sp_o},sp_i={sp_i}"
        out.append((name, est.t_total_s * 1e6, speedup))
        marker = "" if sp == prev_sp else "\n"
        print_fn(f"{marker}sp={sp:.4f} sp_o={sp_o:.2f} sp_i={sp_i:.2f}: "
                 f"{est.t_total_s*1e6:8.1f} us  ({speedup:4.1f}x)  "
                 f"[I-bytes {est.bytes_i/1e6:7.1f} MB]")
        prev_sp = sp
    # trend assertion: within each sparsity level, higher sp_o is faster
    for sp in (0.875, 0.9375):
        rows = [(o, i) for (s, o, i) in ROWS if s == sp]
        times = [estimate_rbgp4mm(spec_for(o, i), N).t_total_s for o, i in rows]
        assert all(times[j] >= times[j + 1] - 1e-12 for j in range(len(times) - 1)), \
            f"Table-2 trend violated at sp={sp}: {times}"
    print_fn("\ntrend check OK: more sparsity in G_o -> faster "
             "(paper Table 2 reproduced)")

    # hardware adaptation: the paper's factor sizes are GPU-register-tuned
    # (G=4, C=1) and underfill the MXU; the TPU-tuned factorization
    # (design_rbgp4: G=16, C=128, large TM) restores the paper's speedups.
    from repro.core import design_rbgp4

    print_fn("\n# TPU-tuned factorizations (design_rbgp4, TM=512) — "
             "DESIGN.md §2 hardware adaptation")
    for sp in (0.75, 0.875, 0.9375):
        spec = design_rbgp4(4096, 4096, sp, target_ui=32)
        est = estimate_rbgp4mm(spec, N)
        speedup = dense.t_total_s / est.t_total_s
        out.append((f"table2,tpu-tuned,sp={sp}", est.t_total_s * 1e6, speedup))
        print_fn(f"sp={sp:.4f} sp_o={spec.sp_o:.3f} sp_i={spec.sp_i:.2f} "
                 f"G={spec.group_rows} C={spec.chunk_cols} TM={spec.tile_m}: "
                 f"{est.t_total_s*1e6:8.1f} us  ({speedup:4.1f}x vs dense)")
        assert speedup > 1.5, f"TPU-tuned rbgp4 should beat dense at sp={sp}"
    return out


def run_plan(print_fn=print) -> list[tuple]:
    """Per-layer sparsity distribution: the budget solver on real model
    shape tables.  Rows report solver wall time (us_per_call) and the
    achieved global density (derived); gates assert the within-one-pow-2-
    step contract and the spectral certification.
    """
    import time

    from repro.configs import get_config
    from repro.sparsity import (
        certify,
        model_matmul_shapes,
        plan_density,
        solve_budget,
    )

    out = []
    print_fn("# Budget solver: per-layer sparsity distribution "
             "(largest-matmul-first, pow-2 steps)")
    for arch, target in (("tinyllama-1.1b", 0.25),
                         ("deepseek-v2-236b", 0.25)):
        shapes = model_matmul_shapes(get_config(arch))
        t0 = time.perf_counter()
        plan = solve_budget(shapes, target_density=target)
        dt = time.perf_counter() - t0
        achieved = plan_density(plan, shapes)
        rep = certify(plan, shapes)["summary"]
        name = f"plan,solve,{arch},target={target}"
        out.append((name, dt * 1e6, achieved))
        levels = {r.spec.sparsity: r.match.count("|") + 1
                  for r in plan.rules if r.spec.is_sparse}
        print_fn(f"{arch}: target {target} -> achieved {achieved:.4f} in "
                 f"{dt*1e3:.0f} ms over {len(shapes)} paths; "
                 f"levels {{sp: n_paths}} = "
                 f"{ {round(s, 4): n for s, n in sorted(levels.items())} }; "
                 f"certify all_ok={rep['all_ok']} "
                 f"({rep['n_proper_ramanujan']} proper factors)")
        assert target / 2 < achieved <= target, \
            f"solver missed the one-pow-2-step window: {achieved} vs {target}"
        assert rep["all_ok"], f"spectral certification failed for {arch}"
    print_fn("\nwithin-one-step + certification gates OK")
    return out


if __name__ == "__main__":
    run()
    run_plan()
