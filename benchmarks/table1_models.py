"""Paper Table 1: VGG19 / WideResNet-40-4 x {dense, unstructured, block,
rbgp4} x sparsity in {50, 75, 87.5, 93.75}%.

Three columns are reproduced:
  * Mem  — analytic, matches the paper's numbers exactly (it is a pure
           function of parameter counts and storage format; fp32 values,
           4-byte indices; first conv + classifier stay dense);
  * Time — per-layer SDMM cost model summed over the network (v5e roofline;
           see kernel_model.py) — reproduces the 5-9x / 2-5x gaps;
  * Acc  — CIFAR itself is offline-unavailable (DESIGN.md §7): accuracy
           *parity* is checked on synthetic class-prototype images with
           ``--train-steps`` (rbgp4 trains to the same accuracy band as
           unstructured at equal sparsity).

Output CSV: name,us_per_call,derived (derived = memory MB).
"""
from __future__ import annotations

import numpy as np

from repro.core import design_rbgp4, RBGP4Spec
from repro.models.vision import VGG19, WideResNet, VisionConfig
from repro.sparsity import SparsityConfig, make_pattern

from .kernel_model import (
    estimate_dense,
    estimate_rbgp4mm,
    estimate_unstructured,
)

SPARSITIES = (0.5, 0.75, 0.875, 0.9375)
PATTERNS = ("unstructured", "block", "rbgp4")
BATCH = 256  # paper: VGG19 trained at batch 256


def sparse_layer_shapes(model_name: str):
    """(m, k, n_spatial) of every *sparsifiable* conv (paper protocol:
    first conv and classifier dense), plus dense-layer param count."""
    if model_name == "vgg19":
        model = VGG19(VisionConfig(name="v"))
        convs = model.convs
        spatial = []
        res = 32
        from repro.models.vision import VGG19_PLAN

        ci = 0
        for v in VGG19_PLAN:
            if v == "M":
                res //= 2
                continue
            spatial.append(res)
            ci += 1
        dense_params = 512 * 10 + 10
        out = []
        dense_extra = 0
        for i, c in enumerate(convs):
            m, k = c.lin.out_features, c.lin.in_features
            if i == 0:
                dense_extra = m * k
                continue
            out.append((m, k, spatial[i] ** 2 * BATCH))
        return out, dense_params + dense_extra
    model = WideResNet(VisionConfig(name="w", depth=40, width=4))
    out = []
    dense_extra = model.stem.lin.out_features * model.stem.lin.in_features
    res_map = {16: 32, 64: 32, 128: 16, 256: 8}
    for b in model.blocks:
        for conv in (b.conv1, b.conv2):
            m, k = conv.lin.out_features, conv.lin.in_features
            res = res_map.get(m, 8)
            out.append((m, k, res * res * 128))  # paper: WRN batch 128
        if b.proj is not None:
            dense_extra += b.proj.lin.out_features * b.proj.lin.in_features
    dense_extra += model.c_final * 10 + 10
    return out, dense_extra


def memory_mb(layers, dense_params, pattern: str, sp: float) -> float:
    total = dense_params * 4
    for m, k, _ in layers:
        nnz = round((1 - sp) * m * k)
        if pattern == "dense":
            total += m * k * 4
        elif pattern == "unstructured":
            total += nnz * 4 + nnz * 4
        elif pattern == "block":
            total += nnz * 4 + (nnz // 16) * 4  # (4,4) blocks
        else:  # rbgp4: succinct index
            cfg = SparsityConfig(pattern="rbgp4", sparsity=sp, min_dim=1)
            pat = make_pattern(cfg, m, k)
            mem = pat.memory_bytes(4, 4)
            total += mem["total"]
    return total / 1e6


def time_us(layers, pattern: str, sp: float) -> float:
    t = 0.0
    for m, k, n in layers:
        if pattern == "dense":
            t += estimate_dense(m, k, n, bytes_per_el=4).t_total_s
        elif pattern == "unstructured":
            t += estimate_unstructured(m, k, n, sp, bytes_per_el=4).t_total_s
        elif pattern == "block":
            spec = RBGP4Spec(g_o=(m // 4, k // 4), g_r=(1, 1), g_i=(1, 1),
                             g_b=(4, 4), sp_o=sp, sp_i=0.0)
            t += estimate_rbgp4mm(spec, n, bytes_per_el=4).t_total_s
        else:
            spec = design_rbgp4(m, k, sp)
            t += estimate_rbgp4mm(spec, n, bytes_per_el=4).t_total_s
    return t * 1e6


def run(print_fn=print, train_steps: int = 0) -> list[tuple]:
    out = []
    for net in ("vgg19", "wrn40-4"):
        layers, dense_params = sparse_layer_shapes(net)
        d_mem = memory_mb(layers, dense_params, "dense", 0.0)
        d_time = time_us(layers, "dense", 0.0)
        print_fn(f"\n# Table 1 — {net} (Mem analytic MB; Time analytic v5e "
                 f"us/forward; paper measured V100 ms)")
        print_fn(f"{'sparsity':>9} {'pattern':>13} {'Mem(MB)':>9} "
                 f"{'Time(us)':>10} {'vs dense':>9} {'vs unstr':>9}")
        print_fn(f"{'0%':>9} {'dense':>13} {d_mem:9.2f} {d_time:10.1f} "
                 f"{1.0:9.2f} {'-':>9}")
        out.append((f"table1,{net},dense,0", d_time, d_mem))
        for sp in SPARSITIES:
            t_unstr = None
            for pattern in PATTERNS:
                mem = memory_mb(layers, dense_params, pattern, sp)
                t = time_us(layers, pattern, sp)
                if pattern == "unstructured":
                    t_unstr = t
                vs_unstr = t_unstr / t if t_unstr else float("nan")
                print_fn(f"{sp*100:8.2f}% {pattern:>13} {mem:9.2f} "
                         f"{t:10.1f} {d_time/t:9.2f} {vs_unstr:9.2f}")
                out.append((f"table1,{net},{pattern},{sp}", t, mem))
    if train_steps:
        out += accuracy_parity(print_fn, train_steps)
    return out


def accuracy_parity(print_fn=print, steps: int = 60) -> list[tuple]:
    """Synthetic-data accuracy parity: rbgp4 vs unstructured at 75%."""
    import jax
    import jax.numpy as jnp
    from repro.configs import TrainConfig
    from repro.data import GaussianClassImages
    from repro.train import Trainer

    print_fn(f"\n# accuracy parity on synthetic CIFAR-shaped data "
             f"({steps} steps, VGG19 depth-reduced)")
    results = []
    # held-out: same prototypes (seed), unseen batch index
    data_test = GaussianClassImages(10, 256, seed=3).batch_at(10_000)
    for pattern in ("dense", "unstructured", "rbgp4"):
        sp_cfg = (SparsityConfig() if pattern == "dense" else
                  SparsityConfig(pattern=pattern, sparsity=0.75, min_dim=32))
        vcfg = VisionConfig(name="v", sparsity=sp_cfg)
        # depth-reduced VGG for CPU: reuse WRN machinery at depth 10
        model = WideResNet(VisionConfig(name="w", depth=10, width=1,
                                        sparsity=sp_cfg))
        params = model.init(jax.random.PRNGKey(0))

        def loss_fn(p, batch):
            logits = model.apply(p, batch["images"], train=True)
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.mean(
                jnp.take_along_axis(ll, batch["labels"][:, None], 1))
            acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
            return loss, {"acc": acc}

        tcfg = TrainConfig(optimizer="sgdm", lr=0.05, schedule="constant",
                           weight_decay=1e-4)
        tr = Trainer(loss_fn, params, tcfg,
                     GaussianClassImages(10, 64, seed=3), checkpoint=False)
        hist = tr.run(steps)
        full = tr.state.full_params()
        logits = model.apply(full, jnp.asarray(data_test["images"]),
                             train=True)
        test_acc = float(jnp.mean(
            jnp.argmax(logits, -1) == jnp.asarray(data_test["labels"])))
        print_fn(f"{pattern:>13}: final-train-acc "
                 f"{hist[-1]['acc']:.3f}  test-acc {test_acc:.3f}")
        results.append((f"table1,parity,{pattern},0.75",
                        hist[-1]["loss"] * 1e6, test_acc))
    return results


if __name__ == "__main__":
    run(train_steps=40)
