"""Quantized sparse storage: int8 leaf blocks vs f32 compact values.

Weight-only PTQ (PR 9): both succinct containers store their values as
dense (G, C) leaf blocks, so each block gets one f32 max-abs scale and
the values drop to int8 — the kernels dequantize in-register against the
f32 accumulator, so value *traffic* falls ~4x while matmul numerics stay
f32.  Three gates, all on the tinyllama-1.1b plan / reduced shapes:

  * **parity** (CPU, every run): the interpret-mode Pallas RHS kernels
    (``rbgp4mm_rhs`` + ``chainmm_rhs``) fed int8 values + scales match
    the XLA dequant oracle (gather-mm over the dequantized values) within
    1e-5 — and the ``quant`` backend off TPU is *bit-identical* to
    serving the dequantized weights, by construction.
  * **bytes** (the storage gate): int8 values + per-leaf-block f32
    scales <= 30% of the f32 compact values, aggregated over every
    sparsified projection of the plan.
  * **tok/s** (analytic v5e roofline): modeled decode throughput through
    the sparse projections >= 1.3x with int8 value streams (the decode
    step is weight-bandwidth-bound, so the 4x value-byte drop shows up
    almost directly).

CSV rows: name,us_per_call,derived (derived = speedup for time rows,
byte ratio for the storage row).
"""
from __future__ import annotations

import numpy as np

from repro.obs import SCHEMA_VERSION  # benchmarks.run gates on this

ARCH = "tinyllama-1.1b"
SPARSITY = 0.75
MIN_DIM = 256
N_DECODE = 16          # tokens per decode step across the live batch
MAX_VALUE_RATIO = 0.30
MIN_DECODE_SPEEDUP = 1.3


def run(print_fn=print) -> list[tuple]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import ChainLayout, RBGP4Layout, RBGP4Spec, design_rbgp
    from repro.kernels import KernelDims, autotune
    from repro.kernels import ref as kref
    from repro.kernels.perf_model import estimate_rbgp4mm_dims
    from repro.sparsity import (
        PatternSpec,
        SparsityPlan,
        model_matmul_shapes,
        quant_storage_bytes,
    )
    from repro.sparsity.quant import (
        dequantize_block_values,
        leaf_block_dims,
        quantize_block_values,
    )

    # -- the tinyllama plan -------------------------------------------------
    spec = PatternSpec(pattern="rbgp4", sparsity=SPARSITY, backend="auto",
                       min_dim=MIN_DIM, quant="int8")
    plan = SparsityPlan.uniform(spec, note="uniform rbgp4 + int8 PTQ")
    shapes = model_matmul_shapes(get_config(ARCH))

    # -- storage: int8 values + scales vs f32 compact values ----------------
    q_bytes = f32_bytes = 0
    n_sparse = 0
    layouts: dict[tuple, RBGP4Layout] = {}
    for path in sorted(shapes):
        m, k, c = shapes[path]
        if not spec.applies_to(m, k):
            continue
        key = (m, k)
        if key not in layouts:
            layouts[key] = plan.pattern_for(path, m, k).layout
        rep = quant_storage_bytes(layouts[key])
        q_bytes += (rep["values"] + rep["scales"]) * c
        f32_bytes += rep["f32_values"] * c
        n_sparse += c
    ratio = q_bytes / f32_bytes
    print_fn(f"# {ARCH} uniform rbgp4@{SPARSITY} plan: {n_sparse} "
             f"sparsified projections ({len(layouts)} distinct shapes), "
             f"quant=int8")
    print_fn(f"  f32 compact values: {f32_bytes/2**20:9.1f} MiB")
    print_fn(f"  int8 + block scales: {q_bytes/2**20:9.1f} MiB "
             f"-> {ratio:.1%} of f32 values")
    assert ratio <= MAX_VALUE_RATIO, (
        f"quantized value bytes {ratio:.1%} > {MAX_VALUE_RATIO:.0%} of f32")

    # -- runtime: modeled decode step, f32 vs int8 value streams ------------
    t_f32 = t_int8 = 0.0
    for (m, k), lay in sorted(layouts.items()):
        count = sum(c for p, (mm, kk, c) in shapes.items()
                    if (mm, kk) == (m, k) and spec.applies_to(mm, kk))
        dims = KernelDims.from_layout(lay)
        tuned_f = autotune.autotune(dims, N_DECODE, dtype="float32",
                                    kind="rhs", platform="v5e-model")
        tuned_q = autotune.autotune(dims, N_DECODE, dtype="float32",
                                    kind="rhs", platform="v5e-model",
                                    value_dtype="int8")
        t_f32 += estimate_rbgp4mm_dims(
            dims, N_DECODE, bytes_per_el=4,
            block_n=tuned_f.block_n).t_total_s * count
        t_int8 += estimate_rbgp4mm_dims(
            dims, N_DECODE, bytes_per_el=4, w_bytes_per_el=1,
            block_n=tuned_q.block_n).t_total_s * count
    speed = t_f32 / t_int8
    tok_f32 = N_DECODE / t_f32
    tok_int8 = N_DECODE / t_int8
    print_fn(f"  decode (modeled, {N_DECODE} tokens/step): "
             f"f32 {tok_f32:,.0f} tok/s, int8 {tok_int8:,.0f} tok/s "
             f"({speed:.2f}x)")
    assert speed >= MIN_DECODE_SPEEDUP, (
        f"modeled decode speedup {speed:.2f}x < {MIN_DECODE_SPEEDUP}x")

    # -- parity gates (reduced shapes, CPU, interpret mode) -----------------
    import importlib

    R = importlib.import_module("repro.kernels.rbgp4mm")
    C = importlib.import_module("repro.kernels.chainmm")

    lay_s = RBGP4Layout(RBGP4Spec(g_o=(4, 4), g_r=(4, 8), g_i=(4, 2),
                                  g_b=(1, 1), sp_o=0.5, sp_i=0.5, seed=3))
    dims_s = KernelDims.from_layout(lay_s)
    kw, kx = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, lay_s.data_shape, jnp.float32)
    x = jax.random.normal(kx, (24, lay_s.k), jnp.float32)
    G, Cc = leaf_block_dims(lay_s)
    q, s = quantize_block_values(w, G, Cc)
    wdq = dequantize_block_values(q, s, G, Cc)
    # XLA dequant oracle: gather-mm over the dequantized values
    y_oracle = kref.compact_gather_mm_rhs(lay_s, wdq, x)
    y_pl = R.rbgp4mm_rhs(dims_s, jnp.asarray(lay_s.adj_o), x, q, scales=s,
                         interpret=True, block_n=8)
    err_c = float(jnp.abs(y_pl - y_oracle).max())

    clay = ChainLayout(design_rbgp(
        128, 128, 0.875, factors=(("ramanujan", 0, 0, 0.5),) * 3, seed=1))
    cdims = C.chain_dims(clay)
    cw = jax.random.normal(kw, clay.data_shape, jnp.float32)
    cx = jax.random.normal(kx, (24, clay.k), jnp.float32)
    Gh, Ch = leaf_block_dims(clay)
    cq, cs = quantize_block_values(cw, Gh, Ch)
    cdq = dequantize_block_values(cq, cs, Gh, Ch)
    y_coracle = cx @ C.chain_unpack_dense(clay, cdq).T
    y_cpl = C.chainmm_rhs(cdims, jnp.asarray(clay.adjs[0], jnp.int32), cx,
                          cq, scales=cs, interpret=True, block_n=8)
    err_h = float(jnp.abs(y_cpl - y_coracle).max())
    print_fn(f"  kernels (interpret): rbgp4mm_rhs int8 max err {err_c:.2e}, "
             f"chainmm_rhs int8 max err {err_h:.2e} vs XLA dequant oracle")
    assert err_c < 1e-5 and err_h < 1e-5

    return [
        ("quant_kernels,decode_f32", t_f32 * 1e6, 1.0),
        ("quant_kernels,decode_int8", t_int8 * 1e6, speed),
        ("quant_kernels,value_bytes_ratio", 0.0, ratio),
    ]


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write rows as {name: us} + derived map")
    args = ap.parse_args()
    rows = run()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        from repro.obs import bench_payload

        with open(args.json, "w") as f:
            json.dump(bench_payload(rows), f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json} "
              f"(schema v{SCHEMA_VERSION})")
