"""Prefix-sharing benchmark: shared-system-prompt serving traffic.

The production shape prefix sharing exists for: every request carries the
same long system prompt plus a short private tail.  Two engines run the
identical workload on the identical page pool — sharing off vs on — and
the run gates on the capacity contract from serve/README.md:

  * GREEDY PARITY — shared and unshared outputs are bit-identical per
    request (sharing moves bits, never recomputes them);
  * HIT RATE — with a warm radix index, >= 80% of the pages the shared
    requests touch at admission come from the index (the system prompt
    dominates each request's footprint by construction);
  * CONCURRENCY — peak concurrently admitted requests at the fixed pool
    is >= 2x the unshared engine's (hit-discounted reservations are what
    turn resident-page reuse into admission headroom).

CSV rows: name,us_per_call(=us per generated token),derived.
Standalone:
  PYTHONPATH=src python -m benchmarks.serve_prefix --json SERVE_PREFIX.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

PAGE = 4
N_BLOCKS = 25          # 24 usable: two unshared requests block the third
SLOTS = 12
MAX_LEN = 48
SYSTEM_TOKENS = 36     # 9 full pages of shared system prompt
TAIL_TOKENS = 3        # private user tail (keeps the last page partial)
MAX_NEW = 4
N_REQUESTS = 10
SEED = 0


def _build(seed):
    import jax

    from repro.configs import apply_sparsity, get_config, reduce_config
    from repro.models import LMModel

    cfg = reduce_config(get_config("tinyllama-1.1b"))
    cfg = apply_sparsity(cfg, pattern="rbgp4", sparsity=0.5, backend="auto",
                         min_dim=64)
    model = LMModel(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _workload(cfg, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    system = rng.integers(1, cfg.vocab_size, SYSTEM_TOKENS).astype(np.int32)
    reqs = []
    for i in range(N_REQUESTS):
        tail = rng.integers(1, cfg.vocab_size, TAIL_TOKENS).astype(np.int32)
        reqs.append({"rid": i,
                     "prompt": np.concatenate([system, tail]),
                     "max_new_tokens": MAX_NEW})
    return system, reqs


def _drain(model, params, workload, *, prefix_cache, warm=None):
    from repro.serve import ContinuousEngine

    eng = ContinuousEngine(model, params, page_size=PAGE, max_slots=SLOTS,
                           max_request_len=MAX_LEN, n_blocks=N_BLOCKS,
                           prefix_cache=prefix_cache)
    if warm is not None:
        # seed the index: one request over the bare system prompt, drained
        # before the wave arrives (a served multi-turn system prompt)
        eng.submit(warm.copy(), 1)
        eng.drain()
    for r in workload:
        eng.submit(r["prompt"], r["max_new_tokens"])
    peak = 0
    t0 = time.perf_counter()
    while not eng.idle:
        eng.step()
        peak = max(peak, eng.scheduler.n_running)
    dt = time.perf_counter() - t0
    out = {r.rid: r.generated for r in eng.requests.values()
           if r.rid < N_REQUESTS}
    return eng, out, peak, dt


def run(print_fn=print, seed: int = SEED) -> list[tuple]:
    os.environ["REPRO_SERVE_CHECKS"] = "1"

    import numpy as np

    from repro.serve.cache import blocks_for_tokens

    model, params = _build(seed)
    system, workload = _workload(model.cfg, seed)
    n_gen = N_REQUESTS * MAX_NEW
    per_req_blocks = blocks_for_tokens(SYSTEM_TOKENS + TAIL_TOKENS + MAX_NEW,
                                       PAGE)
    print_fn(f"# workload: {N_REQUESTS} requests sharing a "
             f"{SYSTEM_TOKENS}-token system prompt (+{TAIL_TOKENS} private "
             f"tail, {MAX_NEW} new); pool {N_BLOCKS} blocks x {PAGE} "
             f"tokens, {per_req_blocks} blocks/request unshared")

    eng_off, out_off, peak_off, dt_off = _drain(
        model, params, workload, prefix_cache=False, warm=system)
    eng_on, out_on, peak_on, dt_on = _drain(
        model, params, workload, prefix_cache=True, warm=system)

    # gate 1: greedy parity, shared vs unshared
    for rid in sorted(out_off):
        if list(out_on[rid]) != list(out_off[rid]):
            raise AssertionError(
                f"request {rid}: shared {out_on[rid]} != unshared "
                f"{out_off[rid]} — sharing changed bits")
    print_fn(f"# parity: {len(out_off)} requests bit-identical "
             f"shared vs unshared")

    # gate 2: page hit rate over the wave's admission-time footprint
    s = eng_on.stats
    touched = N_REQUESTS * blocks_for_tokens(SYSTEM_TOKENS + TAIL_TOKENS,
                                             PAGE)
    hit_rate = s["prefix_hits"] / touched
    print_fn(f"# hit rate: {s['prefix_hits']}/{touched} prompt pages from "
             f"the index ({hit_rate:.1%}); "
             f"{int(s['prefix_hit_tokens'])} tokens never re-prefilled, "
             f"{int(s['prefix_cow_copies'])} COW copies, "
             f"{int(s['prefix_evictions'])} evictions")
    if hit_rate < 0.8:
        raise AssertionError(f"page hit rate {hit_rate:.1%} < 80%")

    # gate 3: >= 2x concurrently admitted requests at the fixed pool
    print_fn(f"# concurrency: peak {peak_on} admitted shared vs "
             f"{peak_off} unshared at {N_BLOCKS - 1} usable blocks")
    if peak_on < 2 * peak_off:
        raise AssertionError(
            f"peak concurrency {peak_on} < 2x unshared ({peak_off})")

    alloc = eng_on.kv.allocator
    alloc.check_invariants()
    idx_blocks = len(eng_on.prefix.blocks())
    assert alloc.n_allocated == idx_blocks, \
        f"leak: {alloc.n_allocated} allocated vs {idx_blocks} indexed"

    per_tok_off = dt_off / max(n_gen, 1) * 1e6
    per_tok_on = dt_on / max(n_gen, 1) * 1e6
    return [
        ("serve_prefix/unshared_tok", per_tok_off, peak_off),
        ("serve_prefix/shared_tok", per_tok_on, peak_on),
        ("serve_prefix/hit_rate", 0.0, hit_rate),
        ("serve_prefix/hit_tokens", 0.0, s["prefix_hit_tokens"]),
        ("serve_prefix/cow_copies", 0.0, s["prefix_cow_copies"]),
        ("serve_prefix/shared_prefills", 0.0, s["shared_prefills"]),
        ("serve_prefix/peak_concurrency_gain", 0.0,
         peak_on / max(peak_off, 1)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    rows = run(print, seed=args.seed)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if args.json:
        payload = {
            "us_per_call": {name: us for name, us, _ in rows},
            "derived": {name: derived for name, _, derived in rows},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
